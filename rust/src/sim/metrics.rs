//! Simulation output metrics: per-class response times, time-averaged
//! occupancy and utilization, Jain fairness, weighted mean response time,
//! and pooling of independent replications into one result with a
//! batch-means CI over all replications' batches.

use crate::util::json::{f64_bits, f64_from_bits, Value};
use crate::util::stats::{jain_index, BatchMeans, TimeAverage, Welford};
use crate::workload::Workload;

/// Departure responses are buffered in a flat `(class, response)` array
/// and folded into the Welford / batch-means accumulators in chunks of
/// this size, keeping the per-event hot path to one `Vec` push and the
/// accumulator state out of the event loop's cache footprint. The fold
/// replays samples **in append order**, so the resulting accumulator
/// state is bit-identical to per-event scalar updates.
const RESPONSE_CHUNK: usize = 256;

/// Collects per-class and aggregate statistics; `reset_at` is called at
/// the end of warmup so reported numbers cover only the measurement
/// window. Response samples accumulate deferred (see [`RESPONSE_CHUNK`]);
/// call [`Metrics::flush_responses`] before reading the accumulators —
/// [`crate::sim::Engine::run`] does this before building its result.
#[derive(Clone)]
pub struct Metrics {
    /// Response-time accumulators per class.
    pub resp: Vec<Welford>,
    /// Batch-means accumulator for the overall response time CI.
    pub resp_all: BatchMeans,
    /// Time-average of jobs-in-system per class.
    pub n_avg: Vec<TimeAverage>,
    /// Time-average of busy servers.
    pub busy_avg: TimeAverage,
    /// Completions counted (post-warmup).
    pub completed: u64,
    /// Measurement window start.
    pub window_start: f64,
    /// Deferred (class, response) samples not yet folded into
    /// `resp` / `resp_all`.
    pending: Vec<(u32, f64)>,
    batch: u64,
}

impl Metrics {
    pub fn new(num_classes: usize, batch: u64) -> Self {
        Self {
            resp: vec![Welford::new(); num_classes],
            resp_all: BatchMeans::new(batch),
            n_avg: vec![TimeAverage::new(); num_classes],
            busy_avg: TimeAverage::new(),
            completed: 0,
            window_start: 0.0,
            pending: Vec::with_capacity(RESPONSE_CHUNK),
            batch,
        }
    }

    pub fn record_response(&mut self, class: usize, t: f64) {
        self.completed += 1;
        self.pending.push((class as u32, t));
        if self.pending.len() >= RESPONSE_CHUNK {
            self.flush_responses();
        }
    }

    /// Fold the deferred response buffer into the accumulators, in
    /// append order (bit-identical to immediate per-event updates).
    pub fn flush_responses(&mut self) {
        let mut pending = std::mem::take(&mut self.pending);
        for &(c, t) in &pending {
            self.resp[c as usize].push(t);
            self.resp_all.push(t);
        }
        pending.clear();
        self.pending = pending;
    }

    pub fn occupancy_changed(&mut self, now: f64, class: usize, n: u32) {
        self.n_avg[class].update(now, n as f64);
    }

    pub fn busy_changed(&mut self, now: f64, busy: u32) {
        self.busy_avg.update(now, busy as f64);
    }

    /// Drop warmup samples: zero all accumulators but re-seed the
    /// time-averages at the current occupancy.
    pub fn reset_at(&mut self, now: f64, n_by_class: &[u32], busy: u32) {
        self.pending.clear();
        for w in &mut self.resp {
            *w = Welford::new();
        }
        self.resp_all.reset();
        for (c, ta) in self.n_avg.iter_mut().enumerate() {
            *ta = TimeAverage::new();
            ta.update(now, n_by_class[c] as f64);
        }
        self.busy_avg = TimeAverage::new();
        self.busy_avg.update(now, busy as f64);
        self.completed = 0;
        self.window_start = now;
    }

    /// Zero everything back to construction state, retaining buffer
    /// allocations (engine reuse across replications).
    pub fn reset_full(&mut self) {
        self.pending.clear();
        for w in &mut self.resp {
            *w = Welford::new();
        }
        self.resp_all.reset();
        for ta in &mut self.n_avg {
            *ta = TimeAverage::new();
        }
        self.busy_avg = TimeAverage::new();
        self.completed = 0;
        self.window_start = 0.0;
    }
}

/// Load-weighted mean response time E[T^w] (§6.1): weights are the
/// per-class offered loads ρ_j = need_j · λ_j / μ_j from the workload
/// spec; classes with no completions contribute zero.
fn weighted_mean_t(wl: &Workload, mean_t: &[f64], count: &[u64]) -> f64 {
    let nc = mean_t.len();
    let rho: Vec<f64> = (0..nc).map(|c| wl.rho_class(c)).collect();
    let rho_tot: f64 = rho.iter().sum();
    if rho_tot > 0.0 {
        (0..nc)
            .map(|c| {
                if count[c] > 0 {
                    rho[c] / rho_tot * mean_t[c]
                } else {
                    0.0
                }
            })
            .sum()
    } else {
        f64::NAN
    }
}

/// Final, immutable result of one simulation run (or a pool of
/// replications).
#[derive(Clone, Debug)]
pub struct SimResult {
    pub policy: String,
    /// Mean response time per class (NaN if no completions).
    pub mean_t: Vec<f64>,
    /// Completions per class.
    pub count: Vec<u64>,
    /// Time-average number in system per class.
    pub mean_n: Vec<f64>,
    /// Overall mean response time.
    pub mean_t_all: f64,
    /// 95% CI half-width for the overall mean (batch means).
    pub ci95: f64,
    /// Load-weighted mean response time E[T^w] (§6.1).
    pub weighted_t: f64,
    /// Jain fairness index over per-class means (Eq. C.1).
    pub jain: f64,
    /// Time-average busy servers / k.
    pub utilization: f64,
    /// Simulated (virtual) measurement time (summed over replications).
    pub sim_time: f64,
    /// Total events processed (incl. warmup).
    pub events: u64,
    /// Completions in the measurement window.
    pub completed: u64,
    /// Wall-clock seconds (summed over replications).
    pub wall_s: f64,
    /// Phase-duration statistics (when tracked).
    pub phases: Option<crate::sim::phase::PhaseStats>,
    /// Occupancy time-series (when recorded).
    pub timeseries: Option<crate::sim::timeseries::Timeseries>,
}

impl SimResult {
    pub fn from_metrics(
        policy: &str,
        m: &Metrics,
        wl: &Workload,
        now: f64,
        events: u64,
        wall_s: f64,
    ) -> SimResult {
        debug_assert!(m.pending.is_empty(), "flush_responses before reducing Metrics");
        let mean_t: Vec<f64> = m.resp.iter().map(|w| w.mean()).collect();
        let count: Vec<u64> = m.resp.iter().map(|w| w.count()).collect();
        let mean_n: Vec<f64> = m.n_avg.iter().map(|ta| ta.average(now)).collect();
        let weighted_t = weighted_mean_t(wl, &mean_t, &count);
        SimResult {
            policy: policy.to_string(),
            jain: jain_index(&mean_t),
            mean_t_all: m.resp_all.mean(),
            ci95: m.resp_all.ci95_half_width(),
            mean_t,
            count,
            mean_n,
            weighted_t,
            utilization: m.busy_avg.average(now) / wl.k as f64,
            sim_time: now - m.window_start,
            events,
            completed: m.completed,
            wall_s,
            phases: None,
            timeseries: None,
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<14} E[T]={:>9.3} ±{:<8.3} E[T^w]={:>10.3} util={:.3} jain={:.3} (n={})",
            self.policy, self.mean_t_all, self.ci95, self.weighted_t, self.utilization, self.jain,
            self.completed
        )
    }
}

/// Everything one finished replication contributes to its point's
/// [`ReplicationPool`], reduced to a wire-friendly form: response
/// accumulators plus the *evaluated* time-average areas and window
/// length (a `TimeAverage` itself never needs to travel). Serializes
/// with bit-exact f64 state, so pooling stats shipped from a remote
/// sweep worker is bit-identical to pooling the local [`Metrics`] they
/// were derived from.
#[derive(Clone, Debug)]
pub struct UnitStats {
    /// Per-class response-time accumulators.
    pub resp: Vec<Welford>,
    /// Overall response-time batch means.
    pub resp_all: BatchMeans,
    /// Per-class ∫N dt over the measurement window.
    pub n_area: Vec<f64>,
    /// ∫busy dt over the measurement window.
    pub busy_area: f64,
    /// Measurement-window length (final time − window start).
    pub window: f64,
    /// Completions in the measurement window.
    pub completed: u64,
    /// Total events processed (incl. warmup).
    pub events: u64,
    /// Wall-clock seconds for the replication.
    pub wall_s: f64,
}

impl UnitStats {
    /// Reduce a finished run's metrics. `now` is the final virtual time;
    /// `events`/`wall_s` the run's event count and wall clock.
    pub fn from_metrics(m: &Metrics, now: f64, events: u64, wall_s: f64) -> UnitStats {
        debug_assert!(m.pending.is_empty(), "flush_responses before reducing Metrics");
        UnitStats {
            resp: m.resp.clone(),
            resp_all: m.resp_all.clone(),
            n_area: m.n_avg.iter().map(|ta| ta.area(now)).collect(),
            busy_area: m.busy_avg.area(now),
            window: now - m.window_start,
            completed: m.completed,
            events,
            wall_s,
        }
    }

    /// Bit-exact JSON form (the sweep wire format).
    pub fn to_json(&self) -> Value {
        let resp: Vec<Value> = self.resp.iter().map(|w| w.to_json()).collect();
        let n_area: Vec<Value> = self.n_area.iter().map(|&a| f64_bits(a)).collect();
        Value::obj()
            .set("resp", Value::Arr(resp))
            .set("resp_all", self.resp_all.to_json())
            .set("n_area", Value::Arr(n_area))
            .set("busy_area", f64_bits(self.busy_area))
            .set("window", f64_bits(self.window))
            .set("completed", self.completed)
            .set("events", self.events)
            .set("wall_s", f64_bits(self.wall_s))
    }

    /// Inverse of [`UnitStats::to_json`].
    pub fn from_json(v: &Value) -> anyhow::Result<UnitStats> {
        let arr = |key: &str| {
            v.get(key)
                .and_then(|x| x.as_arr())
                .ok_or_else(|| anyhow::anyhow!("missing '{key}' array"))
        };
        let bits = |key: &str| {
            v.get(key)
                .and_then(f64_from_bits)
                .ok_or_else(|| anyhow::anyhow!("missing/invalid f64-bits field '{key}'"))
        };
        let count = |key: &str| {
            v.get(key)
                .and_then(|x| x.as_u64())
                .ok_or_else(|| anyhow::anyhow!("missing/invalid u64 field '{key}'"))
        };
        let resp = arr("resp")?
            .iter()
            .map(Welford::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        let n_area = arr("n_area")?
            .iter()
            .map(|x| f64_from_bits(x).ok_or_else(|| anyhow::anyhow!("bad n_area bits")))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let resp_all = v
            .get("resp_all")
            .ok_or_else(|| anyhow::anyhow!("missing 'resp_all'"))
            .and_then(BatchMeans::from_json)?;
        Ok(UnitStats {
            resp,
            resp_all,
            n_area,
            busy_area: bits("busy_area")?,
            window: bits("window")?,
            completed: count("completed")?,
            events: count("events")?,
            wall_s: bits("wall_s")?,
        })
    }
}

/// Pools R independent replications of one simulation point into a
/// single [`SimResult`]:
///
/// * per-class response accumulators merge exactly (Welford merge);
/// * time averages pool as Σ area / Σ window (each replication weighted
///   by its own measurement duration);
/// * every replication's completed batch means enter one CI, so the
///   half-width shrinks like 1/√(total batches) at equal total work,
///   with the replications' independence de-correlating the batches.
pub struct ReplicationPool {
    resp: Vec<Welford>,
    /// Pooled batch-means accumulator ([`BatchMeans::merge`]); None until
    /// the first replication is absorbed.
    resp_all: Option<BatchMeans>,
    n_area: Vec<f64>,
    busy_area: f64,
    window: f64,
    completed: u64,
    events: u64,
    wall_s: f64,
    reps: u32,
}

impl ReplicationPool {
    pub fn new(num_classes: usize) -> ReplicationPool {
        ReplicationPool {
            resp: vec![Welford::new(); num_classes],
            resp_all: None,
            n_area: vec![0.0; num_classes],
            busy_area: 0.0,
            window: 0.0,
            completed: 0,
            events: 0,
            wall_s: 0.0,
            reps: 0,
        }
    }

    /// Fold one finished replication in. `now` is the replication's final
    /// virtual time; `events`/`wall_s` its event count and wall clock.
    pub fn absorb(&mut self, m: &Metrics, now: f64, events: u64, wall_s: f64) {
        self.absorb_stats(&UnitStats::from_metrics(m, now, events, wall_s));
    }

    /// Fold one finished replication's reduced [`UnitStats`] in — the
    /// single merge path for both local metrics and stats deserialized
    /// from a remote sweep worker (bit-identical either way).
    pub fn absorb_stats(&mut self, u: &UnitStats) {
        for (c, w) in u.resp.iter().enumerate() {
            self.resp[c].merge(w);
        }
        match &mut self.resp_all {
            None => self.resp_all = Some(u.resp_all.clone()),
            Some(b) => b.merge(&u.resp_all),
        }
        for (c, &a) in u.n_area.iter().enumerate() {
            self.n_area[c] += a;
        }
        self.busy_area += u.busy_area;
        self.window += u.window;
        self.completed += u.completed;
        self.events += u.events;
        self.wall_s += u.wall_s;
        self.reps += 1;
    }

    pub fn replications(&self) -> u32 {
        self.reps
    }

    /// Build the pooled result. `policy` is the display name.
    pub fn result(&self, policy: &str, wl: &Workload) -> SimResult {
        let mean_t: Vec<f64> = self.resp.iter().map(|w| w.mean()).collect();
        let count: Vec<u64> = self.resp.iter().map(|w| w.count()).collect();
        let mean_n: Vec<f64> = self
            .n_area
            .iter()
            .map(|&a| if self.window > 0.0 { a / self.window } else { f64::NAN })
            .collect();
        let (mean_t_all, ci95) = match &self.resp_all {
            Some(b) => (b.mean(), b.ci95_half_width()),
            None => (f64::NAN, f64::NAN),
        };
        let weighted_t = weighted_mean_t(wl, &mean_t, &count);
        SimResult {
            policy: policy.to_string(),
            jain: jain_index(&mean_t),
            mean_t_all,
            ci95,
            mean_t,
            count,
            mean_n,
            weighted_t,
            utilization: if self.window > 0.0 {
                self.busy_area / self.window / wl.k as f64
            } else {
                f64::NAN
            },
            sim_time: self.window,
            events: self.events,
            completed: self.completed,
            wall_s: self.wall_s,
            phases: None,
            timeseries: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Dist;
    use crate::workload::{ClassSpec, Workload};

    fn wl2() -> Workload {
        Workload::new(
            4,
            vec![
                ClassSpec::new(1, 1.0, Dist::exp_mean(1.0)),
                ClassSpec::new(4, 0.25, Dist::exp_mean(1.0)),
            ],
        )
    }

    #[test]
    fn weighted_mean_uses_load_shares() {
        let wl = wl2();
        let mut m = Metrics::new(2, 10);
        for _ in 0..100 {
            m.record_response(0, 1.0);
            m.record_response(1, 3.0);
        }
        m.flush_responses();
        m.n_avg[0].update(0.0, 1.0);
        m.n_avg[1].update(0.0, 1.0);
        m.busy_avg.update(0.0, 2.0);
        let r = SimResult::from_metrics("t", &m, &wl, 10.0, 200, 0.1);
        // ρ_1 = 1·1/1 = 1, ρ_2 = 4·0.25/1 = 1 → weights 1/2, 1/2.
        assert!((r.weighted_t - 2.0).abs() < 1e-12);
        assert!((r.mean_t_all - 2.0).abs() < 1e-12);
        assert!((r.utilization - 0.5).abs() < 1e-12);
    }

    /// The deferred (class, response) buffer folds in append order, so
    /// the accumulator state — across several full chunks plus a partial
    /// tail — must be bit-identical to immediate per-event updates.
    #[test]
    fn deferred_fold_bit_identical_to_immediate() {
        let mut r = crate::util::rng::Rng::new(9);
        let samples: Vec<(usize, f64)> = (0..1000).map(|_| (r.index(2), r.f64() * 5.0)).collect();
        let mut deferred = Metrics::new(2, 7);
        for &(c, t) in &samples {
            deferred.record_response(c, t);
        }
        deferred.flush_responses();
        let mut direct = Metrics::new(2, 7);
        for &(c, t) in &samples {
            direct.resp[c].push(t);
            direct.resp_all.push(t);
            direct.completed += 1;
        }
        for c in 0..2 {
            assert_eq!(
                deferred.resp[c].to_json().to_string(),
                direct.resp[c].to_json().to_string(),
                "class {c} accumulator diverged"
            );
        }
        assert_eq!(
            deferred.resp_all.to_json().to_string(),
            direct.resp_all.to_json().to_string()
        );
        assert_eq!(deferred.completed, direct.completed);
    }

    /// Absorbing a UnitStats that went through the JSON wire format must
    /// be bit-identical to absorbing the local Metrics directly.
    #[test]
    fn unit_stats_wire_roundtrip_pools_bit_identical() {
        let wl = wl2();
        let mut m = Metrics::new(2, 3);
        let mut r = crate::util::rng::Rng::new(17);
        for i in 0..40 {
            m.record_response(i % 2, r.f64() * 7.0);
        }
        m.flush_responses();
        m.n_avg[0].update(0.0, 1.0);
        m.n_avg[1].update(2.0, 2.0);
        m.busy_avg.update(0.0, 3.0);
        let now = 11.5;

        let mut local = ReplicationPool::new(2);
        local.absorb(&m, now, 123, 0.25);
        let stats = UnitStats::from_metrics(&m, now, 123, 0.25);
        let wire = Value::parse(&stats.to_json().to_string()).unwrap();
        let mut remote = ReplicationPool::new(2);
        remote.absorb_stats(&UnitStats::from_json(&wire).unwrap());

        let a = local.result("t", &wl);
        let b = remote.result("t", &wl);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.events, b.events);
        assert_eq!(a.mean_t_all.to_bits(), b.mean_t_all.to_bits());
        assert_eq!(a.ci95.to_bits(), b.ci95.to_bits());
        assert_eq!(a.weighted_t.to_bits(), b.weighted_t.to_bits());
        assert_eq!(a.utilization.to_bits(), b.utilization.to_bits());
        for c in 0..2 {
            assert_eq!(a.mean_t[c].to_bits(), b.mean_t[c].to_bits());
            assert_eq!(a.mean_n[c].to_bits(), b.mean_n[c].to_bits());
        }
    }

    /// Pooling two identical half-replications must reproduce the means
    /// of the equivalent single run and pool both CIs' batches.
    #[test]
    fn replication_pool_merges_batches_and_means() {
        let wl = wl2();
        let make = |responses: &[f64], t_end: f64| {
            let mut m = Metrics::new(2, 2);
            for &x in responses {
                m.record_response(0, x);
            }
            m.flush_responses();
            m.n_avg[0].update(0.0, 1.0);
            m.n_avg[1].update(0.0, 0.0);
            m.busy_avg.update(0.0, 2.0);
            (m, t_end)
        };
        let (a, ta) = make(&[1.0, 2.0, 3.0, 4.0], 10.0);
        let (b, tb) = make(&[5.0, 6.0, 7.0, 8.0], 10.0);
        let mut pool = ReplicationPool::new(2);
        pool.absorb(&a, ta, 100, 0.1);
        pool.absorb(&b, tb, 100, 0.1);
        assert_eq!(pool.replications(), 2);
        let r = pool.result("t", &wl);
        assert_eq!(r.completed, 8);
        assert_eq!(r.events, 200);
        assert!((r.mean_t[0] - 4.5).abs() < 1e-12);
        assert!((r.mean_t_all - 4.5).abs() < 1e-12);
        // 4 pooled batches of size 2: means 1.5, 3.5, 5.5, 7.5.
        assert!(r.ci95.is_finite() && r.ci95 > 0.0);
        // Time averages pool over the summed 20-unit window.
        assert!((r.mean_n[0] - 1.0).abs() < 1e-12);
        assert!((r.utilization - 0.5).abs() < 1e-12);
        assert!((r.sim_time - 20.0).abs() < 1e-12);
    }
}
