//! Event queue: an **indexed 4-ary min-heap** over (time, sequence).
//!
//! Design goals (vs the former `BinaryHeap<Event>`):
//!
//! * **Cancellable departures.** Every `Departure` entry's heap position
//!   is tracked in a job-slot → heap-index map, so preempting a job
//!   removes its departure event in O(log₄ n) instead of leaving an
//!   epoch-tagged tombstone to be popped (and re-heapified) later. Under
//!   preemptive policies and timer-heavy policies this eliminates all
//!   stale pops from the hot loop.
//! * **Deterministic tie-breaking.** Events carry a monotone sequence
//!   number assigned at push; equal-time events pop in push (FIFO)
//!   order regardless of heap layout. The previous heap's tie order was
//!   an implementation artifact, so exact trajectories differ from the
//!   pre-refactor engine at tie points (documented tie-break change);
//!   same-binary determinism is now guaranteed by construction.
//! * **No NaN swallowing.** Ordering uses `f64::total_cmp` (a total
//!   order) and event times are `debug_assert!`ed finite at push, so a
//!   NaN time can never silently reorder the queue as the old
//!   `partial_cmp(..).unwrap_or(Equal)` comparator could.
//! * **4-ary layout.** Shallower than a binary heap (fewer cache lines
//!   touched per sift) — the classic d-ary heap trade favouring the
//!   pop-heavy DES access pattern.

use crate::policy::JobId;
use crate::sim::job::JobTable;

/// Sentinel heap position ("not scheduled").
const NIL_POS: u32 = u32::MAX;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EventKind {
    /// Next arrival from the workload source.
    Arrival,
    /// Service completion of `job`. Always live: the engine cancels the
    /// event in place when the job is preempted.
    Departure { job: JobId },
    /// Policy-requested timer; discarded unless `seq` is the latest.
    PolicyTimer { seq: u64 },
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Event {
    pub t: f64,
    /// Monotone push sequence number: the deterministic tie-break.
    pub seq: u64,
    pub kind: EventKind,
}

#[inline]
fn before(a: &Event, b: &Event) -> bool {
    match a.t.total_cmp(&b.t) {
        std::cmp::Ordering::Less => true,
        std::cmp::Ordering::Greater => false,
        std::cmp::Ordering::Equal => a.seq < b.seq,
    }
}

/// Indexed 4-ary min-heap event queue.
#[derive(Default)]
pub struct EventQueue {
    heap: Vec<Event>,
    /// dep_pos[job_slot] = heap index of that job's departure (or NIL).
    /// Keyed by the job's slab slot (low 32 bits of the generational id);
    /// a slot has at most one live departure because only Running jobs
    /// have one and a slot holds at most one live job.
    dep_pos: Vec<u32>,
    next_seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self {
            heap: Vec::with_capacity(1024),
            dep_pos: Vec::new(),
            next_seq: 0,
        }
    }

    /// The dep_pos key for a job: its slab slot. Delegates to
    /// [`JobTable::slot_of`] so the generational-id layout is defined in
    /// exactly one place (sim/job.rs).
    #[inline]
    fn job_slot(job: JobId) -> usize {
        JobTable::slot_of(job) as usize
    }

    /// Store `e` at heap index `i`, maintaining the departure map.
    #[inline]
    fn place(&mut self, i: usize, e: Event) {
        self.heap[i] = e;
        if let EventKind::Departure { job } = e.kind {
            self.dep_pos[Self::job_slot(job)] = i as u32;
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        let e = self.heap[i];
        while i > 0 {
            let p = (i - 1) / 4;
            let pe = self.heap[p];
            if before(&e, &pe) {
                self.place(i, pe);
                i = p;
            } else {
                break;
            }
        }
        self.place(i, e);
    }

    fn sift_down(&mut self, mut i: usize) {
        let e = self.heap[i];
        let n = self.heap.len();
        loop {
            let first = 4 * i + 1;
            if first >= n {
                break;
            }
            let last = (first + 4).min(n);
            let mut m = first;
            for c in (first + 1)..last {
                if before(&self.heap[c], &self.heap[m]) {
                    m = c;
                }
            }
            if before(&self.heap[m], &e) {
                let me = self.heap[m];
                self.place(i, me);
                i = m;
            } else {
                break;
            }
        }
        self.place(i, e);
    }

    #[inline]
    pub fn push(&mut self, t: f64, kind: EventKind) {
        debug_assert!(t.is_finite(), "event time must be finite, got {t}");
        if let EventKind::Departure { job } = kind {
            let slot = Self::job_slot(job);
            if slot >= self.dep_pos.len() {
                self.dep_pos.resize(slot + 1, NIL_POS);
            }
            debug_assert!(
                self.dep_pos[slot] == NIL_POS,
                "job already has a scheduled departure"
            );
        }
        let e = Event {
            t,
            seq: self.next_seq,
            kind,
        };
        self.next_seq += 1;
        self.heap.push(e);
        self.sift_up(self.heap.len() - 1);
    }

    /// Time of the earliest event without popping it. The engine merges
    /// the (heap-external) arrival cursor against this: arrivals never
    /// enter the heap, so saturation sweeps skip one push/pop round-trip
    /// per arrival.
    #[inline]
    pub fn peek_t(&self) -> Option<f64> {
        self.heap.first().map(|e| e.t)
    }

    #[inline]
    pub fn pop(&mut self) -> Option<Event> {
        let n = self.heap.len();
        if n == 0 {
            return None;
        }
        let top = self.heap[0];
        if let EventKind::Departure { job } = top.kind {
            self.dep_pos[Self::job_slot(job)] = NIL_POS;
        }
        let last = self.heap.pop().expect("non-empty");
        if n > 1 {
            self.place(0, last);
            self.sift_down(0);
        }
        Some(top)
    }

    /// Remove `job`'s departure event in place. Returns false if no
    /// departure is scheduled for this job (e.g. it was never admitted).
    pub fn cancel_departure(&mut self, job: JobId) -> bool {
        let slot = Self::job_slot(job);
        let Some(&pos) = self.dep_pos.get(slot) else {
            return false;
        };
        if pos == NIL_POS {
            return false;
        }
        let i = pos as usize;
        debug_assert!(
            matches!(self.heap[i].kind, EventKind::Departure { job: j } if j == job),
            "departure map out of sync"
        );
        self.dep_pos[slot] = NIL_POS;
        let last = self.heap.pop().expect("non-empty");
        if i < self.heap.len() {
            self.place(i, last);
            if i > 0 && before(&last, &self.heap[(i - 1) / 4]) {
                self.sift_up(i);
            } else {
                self.sift_down(i);
            }
        }
        true
    }

    /// True iff `job` currently has a scheduled departure.
    #[inline]
    pub fn has_departure(&self, job: JobId) -> bool {
        self.dep_pos
            .get(Self::job_slot(job))
            .map(|&p| p != NIL_POS)
            .unwrap_or(false)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Drop all events and reset the sequence counter (engine reuse).
    /// Allocations (heap arena, departure map) are retained.
    pub fn clear(&mut self) {
        self.heap.clear();
        for p in &mut self.dep_pos {
            *p = NIL_POS;
        }
        self.next_seq = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, EventKind::Arrival);
        q.push(1.0, EventKind::Arrival);
        q.push(2.0, EventKind::PolicyTimer { seq: 0 });
        let times: Vec<f64> = std::iter::from_fn(|| q.pop()).map(|e| e.t).collect();
        assert_eq!(times, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn ties_pop_fifo() {
        let mut q = EventQueue::new();
        for i in 0..10u64 {
            q.push(1.0, EventKind::Departure { job: i });
        }
        assert_eq!(q.len(), 10);
        let mut expect = 0u64;
        while let Some(e) = q.pop() {
            assert_eq!(e.t, 1.0);
            match e.kind {
                EventKind::Departure { job } => {
                    assert_eq!(job, expect, "equal-time events must pop in push order");
                    expect += 1;
                }
                _ => panic!("wrong kind"),
            }
        }
        assert_eq!(expect, 10);
    }

    #[test]
    fn cancel_removes_exactly_the_target() {
        let mut q = EventQueue::new();
        for i in 0..20u64 {
            q.push((i % 7) as f64, EventKind::Departure { job: i });
        }
        assert!(q.cancel_departure(13));
        assert!(!q.cancel_departure(13), "double cancel must fail");
        assert!(!q.cancel_departure(999), "unknown job must fail");
        assert_eq!(q.len(), 19);
        let mut seen = Vec::new();
        let mut last = (f64::NEG_INFINITY, 0u64);
        while let Some(e) = q.pop() {
            assert!((e.t, e.seq) > last, "heap order violated");
            last = (e.t, e.seq);
            if let EventKind::Departure { job } = e.kind {
                seen.push(job);
            }
        }
        assert_eq!(seen.len(), 19);
        assert!(!seen.contains(&13));
    }

    #[test]
    fn cancel_then_reschedule() {
        let mut q = EventQueue::new();
        q.push(5.0, EventKind::Departure { job: 3 });
        q.push(1.0, EventKind::Arrival);
        assert!(q.has_departure(3));
        assert!(q.cancel_departure(3));
        assert!(!q.has_departure(3));
        q.push(2.0, EventKind::Departure { job: 3 });
        assert_eq!(q.pop().unwrap().t, 1.0);
        let e = q.pop().unwrap();
        assert_eq!(e.t, 2.0);
        assert!(matches!(e.kind, EventKind::Departure { job: 3 }));
        assert!(q.is_empty());
    }

    #[test]
    fn clear_resets_sequence_for_reuse() {
        let mut q = EventQueue::new();
        q.push(1.0, EventKind::Arrival);
        q.push(1.0, EventKind::Departure { job: 0 });
        q.clear();
        assert!(q.is_empty());
        assert!(!q.has_departure(0));
        q.push(4.0, EventKind::Arrival);
        assert_eq!(q.pop().unwrap().seq, 0, "sequence restarts after clear");
    }
}
