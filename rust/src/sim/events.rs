//! Event queue: a binary min-heap over event time.

use crate::policy::JobId;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EventKind {
    /// Next arrival from the workload source.
    Arrival,
    /// Service completion of `job` started at epoch `epoch`; discarded if
    /// the job was preempted (epoch mismatch) since it was scheduled.
    Departure { job: JobId, epoch: u32 },
    /// Policy-requested timer; discarded unless `seq` is the latest.
    PolicyTimer { seq: u64 },
}

#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub t: f64,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap on time (BinaryHeap is a max-heap → reverse).
        other
            .t
            .partial_cmp(&self.t)
            .unwrap_or(Ordering::Equal)
    }
}

/// Min-heap event queue.
#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
}

impl EventQueue {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::with_capacity(1024),
        }
    }

    #[inline]
    pub fn push(&mut self, t: f64, kind: EventKind) {
        debug_assert!(t.is_finite(), "event time must be finite");
        self.heap.push(Event { t, kind });
    }

    #[inline]
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn clear(&mut self) {
        self.heap.clear()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, EventKind::Arrival);
        q.push(1.0, EventKind::Arrival);
        q.push(2.0, EventKind::PolicyTimer { seq: 0 });
        let times: Vec<f64> = std::iter::from_fn(|| q.pop()).map(|e| e.t).collect();
        assert_eq!(times, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn ties_are_fine() {
        let mut q = EventQueue::new();
        for _ in 0..10 {
            q.push(1.0, EventKind::Arrival);
        }
        assert_eq!(q.len(), 10);
        while let Some(e) = q.pop() {
            assert_eq!(e.t, 1.0);
        }
    }
}
