//! The discrete-event simulation engine.
//!
//! Event loop: pop the earliest event (arrival / departure / policy
//! timer), apply it to the system state, then repeatedly consult the
//! policy until it makes no further admission/preemption. Feasibility
//! (`Σ need ≤ k`) and non-preemption are enforced here, not trusted to
//! the policy.
//!
//! Hot-path design (see sim/schedule.rs, sim/events.rs, sim/ladder.rs
//! and sim/job.rs):
//!
//! * arrivals never enter the event heap: the engine holds a small
//!   chunk of upcoming arrivals (refilled through
//!   [`ArrivalSource::fill_arrivals`] — one virtual call per chunk, not
//!   per arrival) whose head is merged against the heap head each
//!   iteration; batched sources
//!   ([`SyntheticSource`](crate::workload::SyntheticSource)) pre-generate
//!   interarrivals per class in chunks, and block sources
//!   ([`StreamingTraceSource`](crate::workload::trace::StreamingTraceSource))
//!   copy straight from decoded columns;
//! * policies are notified of per-event state deltas (`on_arrival` /
//!   `on_departure` / `on_swap_epoch`) and consult incrementally — see
//!   the consult-cache protocol in [`crate::policy`];
//! * departures are **cancelled in place** on preemption — there are no
//!   epoch tombstones and no stale pops;
//! * waiting-queue membership is intrusive, so out-of-FIFO admissions
//!   (MSF order, backfilling) are O(1);
//! * an [`Engine`] is **resettable**: [`Engine::reset`] returns it to the
//!   initial state while retaining every allocation (event arena, job
//!   slab, FIFO links, metrics buffers), so repeated replications pay no
//!   construction cost and a reset engine is bit-for-bit equivalent to a
//!   fresh one.

use crate::policy::{Decision, JobId, Policy, SysView};
use crate::sim::events::EventKind;
use crate::sim::job::{ClassFifos, JobTable, QueueIndex};
use crate::sim::metrics::{Metrics, SimResult};
use crate::sim::phase::PhaseStats;
use crate::sim::schedule::{EventScheduleKind, Schedule};
use crate::sim::timeseries::{Timeseries, TimeseriesSpec};
use crate::util::rng::Rng;
use crate::workload::{Arrival, ArrivalSource, ResourceVec, Workload};

/// Arrivals buffered per [`ArrivalSource::fill_arrivals`] refill. Small
/// enough to stay cache-hot, large enough to amortize the dyn dispatch
/// (and, for trace replay, the per-block bookkeeping) to noise.
const ENGINE_ARRIVAL_CHUNK: usize = 64;

#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Completions to measure (after warmup).
    pub target_completions: u64,
    /// Completions to discard as warmup.
    pub warmup_completions: u64,
    /// Safety horizon on virtual time.
    pub max_time: f64,
    /// Record per-class occupancy samples (Fig 1).
    pub timeseries: Option<TimeseriesSpec>,
    /// Track policy phase durations (Fig 4).
    pub track_phases: bool,
    /// Batch size for the batch-means CI.
    pub batch: u64,
    /// Incremental consult cache: `None` follows the process default
    /// ([`crate::policy::consult_cache_enabled`], i.e. on unless
    /// `QS_NO_CONSULT_CACHE` is set); `Some(b)` forces it — the
    /// differential goldens run both sides in one process this way.
    pub consult_cache: Option<bool>,
    /// Event timing structure: `None` follows the process default
    /// ([`EventScheduleKind::from_env`], i.e. the ladder queue unless
    /// `QS_EVENT_SCHEDULE=heap`); `Some(kind)` pins it — the
    /// heap-vs-ladder differential tests and the `sim_*:ladder` bench
    /// targets run both structures in one process this way. Pop order
    /// is bit-identical between the two, so this knob can never change
    /// results — only throughput.
    pub event_schedule: Option<EventScheduleKind>,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            target_completions: 1_000_000,
            warmup_completions: 200_000,
            max_time: f64::INFINITY,
            timeseries: None,
            track_phases: false,
            batch: 1000,
            consult_cache: None,
            event_schedule: None,
        }
    }
}

impl SimConfig {
    /// Scaled-down config for quick runs/tests.
    pub fn quick() -> Self {
        Self {
            target_completions: 100_000,
            warmup_completions: 20_000,
            ..Default::default()
        }
    }

    pub fn with_completions(mut self, target: u64) -> Self {
        self.target_completions = target;
        self.warmup_completions = target / 5;
        self
    }
}

pub struct Engine {
    k: u32,
    needs: Vec<u32>,
    /// Full per-class demand vectors (`needs` is the dim-0 projection).
    demands: Vec<ResourceVec>,
    /// Resource capacity (dim 0 mirrors `k`).
    capacity: ResourceVec,
    cfg: SimConfig,
    wl: Workload,

    now: f64,
    jobs: JobTable,
    /// Per-class intrusive FIFO of waiting jobs.
    fifos: ClassFifos,
    /// Indexed queue summary (Fenwick over need-ranked classes, trigger
    /// counters) the policies consult in O(log C) instead of scanning.
    index: QueueIndex,
    queued: Vec<u32>,
    running: Vec<u32>,
    n_by_class: Vec<u32>,
    used: u32,
    /// Per-dimension usage (dim 0 mirrors `used`).
    used_vec: ResourceVec,

    events: Schedule,
    timer_seq: u64,
    /// Upcoming arrivals, refilled in chunks of [`ENGINE_ARRIVAL_CHUNK`]
    /// from the source; `arrivals[arrivals_pos]` is the pending cursor.
    arrivals: Vec<Arrival>,
    arrivals_pos: usize,
    /// The source returned a short (or empty) chunk: no refills left.
    src_done: bool,

    metrics: Metrics,
    phases: PhaseStats,
    ts: Option<Timeseries>,

    events_processed: u64,
    completions_total: u64,
    warmed: bool,
}

impl Engine {
    pub fn new(wl: &Workload, cfg: SimConfig) -> Engine {
        let nc = wl.num_classes();
        let ts = cfg.timeseries.as_ref().map(|s| Timeseries::new(s, nc));
        let schedule = cfg
            .event_schedule
            .unwrap_or_else(EventScheduleKind::from_env);
        let mut jobs = JobTable::new();
        jobs.set_prefix_threshold(wl.k as u64);
        Engine {
            k: wl.k,
            needs: wl.needs(),
            demands: wl.demands(),
            capacity: wl.capacity,
            metrics: Metrics::new(nc, cfg.batch),
            cfg,
            wl: wl.clone(),
            now: 0.0,
            jobs,
            fifos: ClassFifos::new(nc),
            index: QueueIndex::with_demands(&wl.demands()),
            queued: vec![0; nc],
            running: vec![0; nc],
            n_by_class: vec![0; nc],
            used: 0,
            used_vec: ResourceVec::zero(wl.dims()),
            events: Schedule::new(schedule),
            timer_seq: 0,
            arrivals: Vec::with_capacity(ENGINE_ARRIVAL_CHUNK),
            arrivals_pos: 0,
            src_done: false,
            phases: PhaseStats::new(),
            ts,
            events_processed: 0,
            completions_total: 0,
            warmed: false,
        }
    }

    /// Return to the initial state while retaining all allocations, so a
    /// subsequent [`run`](Engine::run) behaves exactly like the first run
    /// of a freshly constructed engine (bit-identical given the same
    /// source/policy/rng).
    pub fn reset(&mut self) {
        self.now = 0.0;
        self.jobs.clear();
        self.fifos.clear();
        self.index.clear();
        for q in &mut self.queued {
            *q = 0;
        }
        for r in &mut self.running {
            *r = 0;
        }
        for n in &mut self.n_by_class {
            *n = 0;
        }
        self.used = 0;
        self.used_vec = ResourceVec::zero(self.capacity.dims());
        self.events.clear();
        self.timer_seq = 0;
        self.arrivals.clear();
        self.arrivals_pos = 0;
        self.src_done = false;
        self.metrics.reset_full();
        self.phases = PhaseStats::new();
        if let Some(spec) = self.cfg.timeseries.as_ref() {
            self.ts = Some(Timeseries::new(spec, self.needs.len()));
        }
        self.events_processed = 0;
        self.completions_total = 0;
        self.warmed = false;
    }

    /// The metrics accumulated by the last [`run`](Engine::run) (valid
    /// until the next `reset`). Used by the replication runner to pool
    /// batch means across independent runs.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Current virtual time.
    pub fn now(&self) -> f64 {
        self.now
    }

    fn view(&self) -> SysView<'_> {
        #[cfg(debug_assertions)]
        self.index.assert_consistent(&self.queued, &self.running);
        SysView {
            now: self.now,
            k: self.k,
            used: self.used,
            capacity: self.capacity,
            used_vec: self.used_vec,
            needs: &self.needs,
            demands: &self.demands,
            queued: &self.queued,
            running: &self.running,
            jobs: &self.jobs,
            fifos: &self.fifos,
            index: &self.index,
        }
    }

    /// Refill the arrival chunk from the source. One virtual call per
    /// [`ENGINE_ARRIVAL_CHUNK`] arrivals; identical draw order to
    /// one-at-a-time pulls because `fill_arrivals` consumes the RNG
    /// exactly as repeated `next_arrival` would.
    #[inline]
    fn refill_arrivals(&mut self, src: &mut dyn ArrivalSource, rng: &mut Rng) {
        self.arrivals.clear();
        self.arrivals_pos = 0;
        let n = src.fill_arrivals(rng, &mut self.arrivals, ENGINE_ARRIVAL_CHUNK);
        if n < ENGINE_ARRIVAL_CHUNK {
            self.src_done = true;
        }
    }

    /// True once the source is exhausted and every buffered arrival has
    /// been consumed (finite traces; a live synthetic source never is).
    #[inline]
    fn arrivals_exhausted(&self) -> bool {
        self.src_done && self.arrivals_pos == self.arrivals.len()
    }

    /// Run to completion; returns the aggregated result.
    ///
    /// Arrivals bypass the event heap entirely: the engine buffers a
    /// chunk of upcoming arrivals and merges its head against
    /// [`EventQueue::peek_t`] each iteration (arrivals win exact-time
    /// ties — deterministic, and measure-zero under continuous
    /// interarrivals), so the heap holds only departures and policy
    /// timers and the source's virtual dispatch is paid once per chunk.
    pub fn run(
        &mut self,
        src: &mut dyn ArrivalSource,
        policy: &mut dyn Policy,
        rng: &mut Rng,
    ) -> SimResult {
        let wall0 = std::time::Instant::now();
        let stop_at = self.cfg.warmup_completions + self.cfg.target_completions;
        if self.cfg.warmup_completions == 0 {
            self.warmed = true;
        }
        policy.set_consult_cache(
            self.cfg
                .consult_cache
                .unwrap_or_else(crate::policy::consult_cache_enabled),
        );

        // Prime the arrival buffer.
        self.src_done = false;
        self.refill_arrivals(src, rng);

        let mut decision = Decision::default();
        loop {
            // `peek_t` is `&mut`: the ladder schedule refills its sorted
            // bottom tier lazily (a no-op for the heap).
            let heap_t = self.events.peek_t();
            let pending_t = self.arrivals.get(self.arrivals_pos).map(|a| a.t);
            let take_arrival = match (pending_t, heap_t) {
                (Some(at), Some(ht)) => at <= ht,
                (Some(_), None) => true,
                (None, _) => false,
            };
            if take_arrival {
                let a = self.arrivals[self.arrivals_pos];
                self.arrivals_pos += 1;
                debug_assert!(a.t >= self.now - 1e-9);
                if let Some(ts) = self.ts.as_mut() {
                    ts.advance(a.t, &self.n_by_class);
                }
                self.now = a.t;
                if self.now > self.cfg.max_time {
                    break;
                }
                self.events_processed += 1;
                let class = a.class;
                self.apply_arrival(a);
                policy.on_arrival(class, self.needs[class]);
                if self.arrivals_pos == self.arrivals.len() && !self.src_done {
                    self.refill_arrivals(src, rng);
                }
            } else {
                let Some(ev) = self.events.pop() else {
                    break; // arrival stream exhausted and heap empty
                };
                debug_assert!(ev.t >= self.now - 1e-9);
                if let Some(ts) = self.ts.as_mut() {
                    ts.advance(ev.t, &self.n_by_class);
                }
                self.now = ev.t;
                if self.now > self.cfg.max_time {
                    break;
                }
                self.events_processed += 1;

                match ev.kind {
                    EventKind::Arrival => unreachable!("arrivals bypass the event heap"),
                    EventKind::Departure { job } => {
                        let class = self.jobs.class(job);
                        let need = self.jobs.need(job);
                        self.apply_departure(job);
                        policy.on_departure(class, need);
                        if self.completions_total >= stop_at {
                            break;
                        }
                    }
                    EventKind::PolicyTimer { seq } => {
                        if seq != self.timer_seq {
                            continue; // superseded timer
                        }
                        // A finite source has drained and no jobs remain:
                        // a recurring policy timer (MSR's swap clock)
                        // would otherwise spin virtual time forever.
                        if self.arrivals_exhausted()
                            && self.n_by_class.iter().all(|&n| n == 0)
                        {
                            break;
                        }
                        policy.on_timer(self.now);
                    }
                }
            }

            self.consult_policy(policy, &mut decision);

            if self.cfg.track_phases {
                let label = policy.phase_label(&self.view());
                self.phases.observe(self.now, label);
            }

            // Warmup boundary: reset accumulators once.
            if !self.warmed && self.completions_total >= self.cfg.warmup_completions {
                self.warmed = true;
                self.metrics.reset_at(self.now, &self.n_by_class, self.used);
                self.phases.reset_at(self.now);
            }
        }

        self.phases.finish(self.now);
        // Fold any responses still sitting in the deferred-accumulation
        // buffer before anything reads the accumulators.
        self.metrics.flush_responses();
        let mut result = SimResult::from_metrics(
            &policy.name(),
            &self.metrics,
            &self.wl,
            self.now,
            self.events_processed,
            wall0.elapsed().as_secs_f64(),
        );
        result.phases = if self.cfg.track_phases {
            Some(self.phases.clone())
        } else {
            None
        };
        result.timeseries = self.ts.clone();
        result
    }

    fn apply_arrival(&mut self, a: Arrival) {
        let need = self.needs[a.class];
        debug_assert!(a.size >= 0.0);
        let id = self.jobs.insert(a.class, need, a.size, a.t);
        self.fifos.push_back(a.class, JobTable::slot_of(id));
        self.index.on_enqueue(a.class);
        self.queued[a.class] += 1;
        self.n_by_class[a.class] += 1;
        self.metrics
            .occupancy_changed(self.now, a.class, self.n_by_class[a.class]);
    }

    fn apply_departure(&mut self, id: JobId) {
        debug_assert!(self.jobs.is_running(id), "departure for non-running job");
        let class = self.jobs.class(id);
        let need = self.jobs.need(id);
        let arrival = self.jobs.arrival(id);
        self.used -= need;
        self.used_vec.sub_assign(&self.demands[class]);
        self.index.on_depart(class);
        self.running[class] -= 1;
        self.n_by_class[class] -= 1;
        self.jobs.remove(id);
        self.completions_total += 1;
        if self.warmed {
            self.metrics.record_response(class, self.now - arrival);
        }
        self.metrics
            .occupancy_changed(self.now, class, self.n_by_class[class]);
        self.metrics.busy_changed(self.now, self.used);
    }

    fn consult_policy(&mut self, policy: &mut dyn Policy, decision: &mut Decision) {
        let preemptive = policy.is_preemptive();
        loop {
            decision.clear();
            policy.schedule(&self.view(), decision);
            if let Some(t) = decision.set_timer {
                debug_assert!(t >= self.now);
                self.timer_seq += 1;
                self.events
                    .push(t.max(self.now), EventKind::PolicyTimer { seq: self.timer_seq });
            }
            if decision.admit.is_empty() && decision.preempt.is_empty() {
                break;
            }
            assert!(
                preemptive || decision.preempt.is_empty(),
                "non-preemptive policy {} attempted preemption",
                policy.name()
            );
            for &id in &decision.preempt {
                self.do_preempt(id);
            }
            for &id in &decision.admit {
                self.do_admit(id, policy);
            }
            // The service set swapped: let the policy refresh whatever
            // consult-cache state its own decision invalidated.
            policy.on_swap_epoch();
        }
    }

    fn do_preempt(&mut self, id: JobId) {
        // Cancel the in-flight departure in place: no tombstones.
        let canceled = self.events.cancel_departure(id);
        debug_assert!(canceled, "preempted job had no scheduled departure");
        self.jobs.preempt(id, self.now);
        let class = self.jobs.class(id);
        let need = self.jobs.need(id);
        self.used -= need;
        self.used_vec.sub_assign(&self.demands[class]);
        self.index.on_preempt(class);
        self.running[class] -= 1;
        self.queued[class] += 1;
        // Preempted jobs rejoin the front of their class FIFO; the
        // arrival-order list still holds them at their original position.
        self.fifos.push_front(class, JobTable::slot_of(id));
        self.metrics.busy_changed(self.now, self.used);
    }

    fn do_admit(&mut self, id: JobId, policy: &dyn Policy) {
        assert!(
            self.jobs.is_queued(id),
            "policy {} admitted a non-queued job",
            policy.name()
        );
        let class = self.jobs.class(id);
        let need = self.jobs.need(id);
        let demand = self.demands[class];
        assert!(
            demand.fits_in(&self.capacity.saturating_sub(&self.used_vec)),
            "policy {} violated capacity: used={} demand={} capacity={}",
            policy.name(),
            self.used_vec,
            demand,
            self.capacity
        );
        // O(1) removal from any FIFO position (intrusive links).
        self.fifos.remove(class, JobTable::slot_of(id));
        self.jobs.start_service(id, self.now);
        let depart_at = self.now + self.jobs.remaining(id);
        self.used += need;
        self.used_vec.add_assign(&demand);
        self.index.on_admit(class);
        self.running[class] += 1;
        self.queued[class] -= 1;
        self.events
            .push(depart_at, EventKind::Departure { job: id });
        self.metrics.busy_changed(self.now, self.used);
    }

    pub fn events_processed(&self) -> u64 {
        self.events_processed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Dist;
    use crate::policy::Fcfs;
    use crate::workload::{ClassSpec, SyntheticSource, Workload};

    /// M/M/1 sanity: k=1, single class, FCFS ⇒ E[T] = 1/(μ−λ).
    #[test]
    fn mm1_mean_response_time() {
        let wl = Workload::new(1, vec![ClassSpec::new(1, 0.5, Dist::Exp { mu: 1.0 })]);
        let mut src = SyntheticSource::new(wl.clone());
        let mut rng = Rng::new(7);
        let mut engine = Engine::new(&wl, SimConfig::quick());
        let mut policy = Fcfs::new();
        let r = engine.run(&mut src, &mut policy, &mut rng);
        let expect = 1.0 / (1.0 - 0.5);
        assert!(
            (r.mean_t_all - expect).abs() < 0.08,
            "E[T]={} expect {expect}",
            r.mean_t_all
        );
        // Little's law cross-check: E[N] = λ E[T].
        assert!((r.mean_n[0] - 0.5 * r.mean_t_all).abs() < 0.08);
        // Utilization ≈ ρ.
        assert!((r.utilization - 0.5).abs() < 0.02);
    }

    /// M/M/k with k=4 ⇒ Erlang-C formula.
    #[test]
    fn mmk_matches_erlang_c() {
        let (k, lam, mu) = (4u32, 3.0, 1.0);
        let wl = Workload::new(k, vec![ClassSpec::new(1, lam, Dist::Exp { mu })]);
        let mut src = SyntheticSource::new(wl.clone());
        let mut rng = Rng::new(11);
        let mut engine = Engine::new(&wl, SimConfig::quick());
        let mut policy = Fcfs::new();
        let r = engine.run(&mut src, &mut policy, &mut rng);
        let expect = crate::analysis::mmk::mean_response_time(k, lam, mu);
        assert!(
            (r.mean_t_all - expect).abs() / expect < 0.03,
            "E[T]={} expect {expect}",
            r.mean_t_all
        );
    }

    /// Preemptive policies exercise cancel/reschedule on the indexed
    /// heap; the run must stay self-consistent end to end.
    #[test]
    fn preemptive_run_is_consistent() {
        let wl = Workload::one_or_all(8, 3.0, 0.9, 1.0, 1.0);
        let cfg = SimConfig {
            target_completions: 20_000,
            warmup_completions: 4_000,
            ..Default::default()
        };
        let r = crate::sim::run_policy(&wl, &"server-filling".parse().unwrap(), &cfg, 3).unwrap();
        assert_eq!(r.completed, 20_000);
        assert!(r.mean_t_all.is_finite() && r.mean_t_all > 0.0);
        assert!(r.utilization <= 1.0 + 1e-9);
    }

    /// reset() must reproduce the first run exactly.
    #[test]
    fn reset_reproduces_run() {
        let wl = Workload::one_or_all(4, 1.5, 0.9, 1.0, 1.0);
        let cfg = SimConfig {
            target_completions: 10_000,
            warmup_completions: 2_000,
            ..Default::default()
        };
        let mut engine = Engine::new(&wl, cfg);
        let run = |e: &mut Engine| {
            let mut src = SyntheticSource::new(wl.clone());
            let mut rng = Rng::new(42);
            let mut p = crate::policy::build(&"msfq:3".parse().unwrap(), &wl).unwrap();
            e.run(&mut src, p.as_mut(), &mut rng)
        };
        let a = run(&mut engine);
        engine.reset();
        let b = run(&mut engine);
        assert_eq!(a.events, b.events);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.mean_t_all.to_bits(), b.mean_t_all.to_bits());
    }
}
