//! Occupancy time-series recording (reproduces Fig 1).

/// Sampling spec: record per-class occupancy every `dt` (virtual time),
/// capped at `max_samples` (recording stops after the cap).
#[derive(Clone, Debug)]
pub struct TimeseriesSpec {
    pub dt: f64,
    pub max_samples: usize,
}

impl Default for TimeseriesSpec {
    fn default() -> Self {
        Self {
            dt: 1.0,
            max_samples: 100_000,
        }
    }
}

/// Recorded samples: time plus jobs-in-system per class.
#[derive(Clone, Debug, Default)]
pub struct Timeseries {
    pub t: Vec<f64>,
    /// per_class[c][i] = occupancy of class c at t[i].
    pub per_class: Vec<Vec<u32>>,
    next_t: f64,
    dt: f64,
    cap: usize,
}

impl Timeseries {
    pub fn new(spec: &TimeseriesSpec, num_classes: usize) -> Self {
        Self {
            t: Vec::new(),
            per_class: vec![Vec::new(); num_classes],
            next_t: 0.0,
            dt: spec.dt,
            cap: spec.max_samples,
        }
    }

    /// Called at each event with the *pre-event* state held on [prev, now).
    /// Emits all sample points that fall in that interval.
    #[inline]
    pub fn advance(&mut self, now: f64, n_by_class: &[u32]) {
        while self.next_t <= now && self.t.len() < self.cap {
            self.t.push(self.next_t);
            for (c, v) in self.per_class.iter_mut().enumerate() {
                v.push(n_by_class[c]);
            }
            self.next_t += self.dt;
        }
    }

    pub fn len(&self) -> usize {
        self.t.len()
    }

    pub fn is_empty(&self) -> bool {
        self.t.is_empty()
    }

    /// Write to CSV: t, n_<class0>, n_<class1>, ...
    pub fn write_csv(
        &self,
        path: impl AsRef<std::path::Path>,
        class_names: &[String],
    ) -> std::io::Result<()> {
        let mut header = vec!["t".to_string()];
        header.extend(class_names.iter().map(|n| format!("n_{n}")));
        let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut w = crate::util::csv::CsvWriter::create(path, &header_refs)?;
        for i in 0..self.t.len() {
            let mut row = vec![self.t[i]];
            for c in &self.per_class {
                row.push(c[i] as f64);
            }
            w.row_f64(&row)?;
        }
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_on_grid() {
        let mut ts = Timeseries::new(
            &TimeseriesSpec {
                dt: 1.0,
                max_samples: 100,
            },
            2,
        );
        ts.advance(0.5, &[1, 0]); // covers t=0
        ts.advance(2.5, &[3, 1]); // covers t=1,2
        assert_eq!(ts.t, vec![0.0, 1.0, 2.0]);
        assert_eq!(ts.per_class[0], vec![1, 3, 3]);
        assert_eq!(ts.per_class[1], vec![0, 1, 1]);
    }

    #[test]
    fn cap_respected() {
        let mut ts = Timeseries::new(
            &TimeseriesSpec {
                dt: 0.1,
                max_samples: 5,
            },
            1,
        );
        ts.advance(100.0, &[7]);
        assert_eq!(ts.len(), 5);
    }
}
