//! Experiment/system configuration: JSON-backed specs for workloads,
//! policies and run parameters, so experiments are declarative and
//! reproducible (`quickswap simulate --config exp.json`).

use crate::dist::Dist;
use crate::policy::PolicyId;
use crate::sim::SimConfig;
use crate::util::json::Value;
use crate::workload::{ClassSpec, ResourceVec, Workload};

/// Declarative experiment: a workload, a set of policies, run controls.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    pub workload: Workload,
    pub policies: Vec<PolicyId>,
    pub sim: SimConfig,
    pub seed: u64,
    pub replications: u32,
}

impl ExperimentConfig {
    pub fn from_json(text: &str) -> anyhow::Result<ExperimentConfig> {
        let v = Value::parse(text)?;
        let name = v
            .get("name")
            .and_then(|x| x.as_str())
            .unwrap_or("experiment")
            .to_string();
        let workload = parse_workload(
            v.get("workload")
                .ok_or_else(|| anyhow::anyhow!("missing 'workload'"))?,
        )?;
        let policies = match v.get("policies").and_then(|x| x.as_arr()) {
            Some(arr) => arr
                .iter()
                .map(|p| {
                    p.as_str()
                        .ok_or_else(|| anyhow::anyhow!("non-string policy"))
                        .and_then(PolicyId::parse)
                })
                .collect::<anyhow::Result<Vec<PolicyId>>>()?,
            None => vec![PolicyId::Msfq(None)],
        };
        let mut sim = SimConfig::default();
        if let Some(s) = v.get("sim") {
            if let Some(t) = s.get("target_completions").and_then(|x| x.as_u64()) {
                sim.target_completions = t;
            }
            if let Some(w) = s.get("warmup_completions").and_then(|x| x.as_u64()) {
                sim.warmup_completions = w;
            }
            if let Some(m) = s.get("max_time").and_then(|x| x.as_f64()) {
                sim.max_time = m;
            }
            if s.get("track_phases").and_then(|x| x.as_bool()) == Some(true) {
                sim.track_phases = true;
            }
            if let Some(es) = s.get("event_schedule").and_then(|x| x.as_str()) {
                sim.event_schedule = Some(match es {
                    "heap" => crate::sim::EventScheduleKind::Heap,
                    "ladder" => crate::sim::EventScheduleKind::Ladder,
                    other => anyhow::bail!("sim.event_schedule must be heap|ladder, got '{other}'"),
                });
            }
        }
        let seed = v.get("seed").and_then(|x| x.as_u64()).unwrap_or(1);
        let replications = v
            .get("replications")
            .and_then(|x| x.as_u64())
            .unwrap_or(1) as u32;
        Ok(ExperimentConfig {
            name,
            workload,
            policies,
            sim,
            seed,
            replications,
        })
    }
}

/// Workload spec:
/// `{"kind":"one_or_all","k":32,"lambda":7.5,"p1":0.9,"mu1":1,"muk":1}`,
/// `{"kind":"four_class","lambda":4.0}`, `{"kind":"borg","lambda":4.0}`,
/// `{"kind":"multires","k":16,"mem":64,"lambda":4.0}`, or
/// `{"kind":"custom","k":8,"classes":[{"need":1,"rate":1.0,"mean":1.0}]}`.
/// Custom classes may give a multiresource `"demand":[servers,mem,...]`
/// array instead of a scalar `"need"`; a custom `"capacity":[...]` array
/// then sizes the extra dimensions (defaults to `k` in dimension 0).
pub fn parse_workload(v: &Value) -> anyhow::Result<Workload> {
    let kind = v
        .get("kind")
        .and_then(|x| x.as_str())
        .ok_or_else(|| anyhow::anyhow!("workload needs 'kind'"))?;
    let f = |key: &str, d: f64| v.get(key).and_then(|x| x.as_f64()).unwrap_or(d);
    let wl = match kind {
        "one_or_all" => {
            let k = v
                .get("k")
                .and_then(|x| x.as_u64())
                .ok_or_else(|| anyhow::anyhow!("one_or_all needs 'k'"))? as u32;
            Ok(Workload::one_or_all(
                k,
                f("lambda", 1.0),
                f("p1", 0.9),
                f("mu1", 1.0),
                f("muk", 1.0),
            ))
        }
        "four_class" => Ok(Workload::four_class(f("lambda", 1.0))),
        "borg" => Ok(crate::workload::borg::borg_workload(f("lambda", 1.0))),
        "multires" => {
            let k = v
                .get("k")
                .and_then(|x| x.as_u64())
                .ok_or_else(|| anyhow::anyhow!("multires needs 'k'"))? as u32;
            let mem = v
                .get("mem")
                .and_then(|x| x.as_u64())
                .ok_or_else(|| anyhow::anyhow!("multires needs 'mem'"))? as u32;
            Ok(Workload::multires(k, mem, f("lambda", 1.0)))
        }
        "custom" => {
            let k = v
                .get("k")
                .and_then(|x| x.as_u64())
                .ok_or_else(|| anyhow::anyhow!("custom needs 'k'"))? as u32;
            let capacity = match v.get("capacity") {
                Some(cap) => {
                    let dims = resource_dims(cap)
                        .ok_or_else(|| anyhow::anyhow!("'capacity' must be an array of numbers"))?;
                    anyhow::ensure!(
                        dims.first() == Some(&k),
                        "capacity dimension 0 must equal 'k'"
                    );
                    ResourceVec::new(&dims)
                }
                None => ResourceVec::scalar(k),
            };
            let classes = v
                .get("classes")
                .and_then(|x| x.as_arr())
                .ok_or_else(|| anyhow::anyhow!("custom needs 'classes'"))?;
            let mut specs = Vec::new();
            for c in classes {
                let demand = match c.get("demand") {
                    Some(d) => {
                        let dims = resource_dims(d).ok_or_else(|| {
                            anyhow::anyhow!("class 'demand' must be an array of numbers")
                        })?;
                        ResourceVec::new(&dims)
                    }
                    None => {
                        let need = c
                            .get("need")
                            .and_then(|x| x.as_u64())
                            .ok_or_else(|| anyhow::anyhow!("class needs 'need' or 'demand'"))?
                            as u32;
                        ResourceVec::scalar(need)
                    }
                };
                anyhow::ensure!(
                    demand.dims() == capacity.dims(),
                    "class demand has {} dimensions but the capacity has {}",
                    demand.dims(),
                    capacity.dims()
                );
                anyhow::ensure!(
                    demand.fits_in(&capacity),
                    "class demand {demand} exceeds the capacity {capacity}"
                );
                let rate = c
                    .get("rate")
                    .and_then(|x| x.as_f64())
                    .ok_or_else(|| anyhow::anyhow!("class needs 'rate'"))?;
                let mean = c.get("mean").and_then(|x| x.as_f64()).unwrap_or(1.0);
                let scv = c.get("scv").and_then(|x| x.as_f64()).unwrap_or(1.0);
                let dist = if (scv - 1.0).abs() < 1e-12 {
                    Dist::exp_mean(mean)
                } else if scv > 1.0 {
                    Dist::hyper2_mean_scv(mean, scv)
                } else {
                    // SCV < 1 → Erlang with the nearest stage count.
                    let stages = (1.0 / scv).round().max(1.0) as u32;
                    Dist::Erlang {
                        k: stages,
                        rate: stages as f64 / mean,
                    }
                };
                specs.push(ClassSpec::with_demand(demand, rate, dist));
            }
            Ok(Workload::with_capacity(capacity, specs))
        }
        other => anyhow::bail!("unknown workload kind '{other}'"),
    }?;
    // Optional nonstationary arrival-rate curve, e.g.
    // `"rate_curve": {"kind":"diurnal","period":24,"amp":0.5}`.
    match v.get("rate_curve") {
        Some(rc) => {
            let curve = crate::workload::rate::rate_curve_from_json(rc)
                .map_err(|e| anyhow::anyhow!("rate_curve: {e}"))?;
            curve.validate().map_err(|e| anyhow::anyhow!("rate_curve: {e}"))?;
            Ok(wl.with_rate_curve(curve))
        }
        None => Ok(wl),
    }
}

/// An array-of-numbers JSON value as resource dimensions.
fn resource_dims(v: &Value) -> Option<Vec<u32>> {
    let arr = v.as_arr()?;
    arr.iter()
        .map(|x| x.as_u64().map(|n| n as u32))
        .collect::<Option<Vec<u32>>>()
        .filter(|dims| !dims.is_empty())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_experiment() {
        let cfg = ExperimentConfig::from_json(
            r#"{
              "name": "fig3",
              "workload": {"kind": "one_or_all", "k": 32, "lambda": 7.5, "p1": 0.9},
              "policies": ["msf", "msfq:31", "fcfs"],
              "sim": {"target_completions": 1000, "warmup_completions": 100},
              "seed": 7, "replications": 3
            }"#,
        )
        .unwrap();
        assert_eq!(cfg.name, "fig3");
        assert_eq!(cfg.workload.k, 32);
        assert_eq!(cfg.policies.len(), 3);
        assert_eq!(cfg.sim.target_completions, 1000);
        assert_eq!(cfg.replications, 3);
    }

    #[test]
    fn parses_event_schedule() {
        let mk = |es: &str| {
            ExperimentConfig::from_json(&format!(
                r#"{{"workload": {{"kind": "four_class", "lambda": 1.0}},
                     "sim": {{"event_schedule": "{es}"}}}}"#
            ))
        };
        assert_eq!(
            mk("heap").unwrap().sim.event_schedule,
            Some(crate::sim::EventScheduleKind::Heap)
        );
        assert_eq!(
            mk("ladder").unwrap().sim.event_schedule,
            Some(crate::sim::EventScheduleKind::Ladder)
        );
        assert!(mk("nope").is_err());
        // Unset: follow the process default.
        let cfg = ExperimentConfig::from_json(
            r#"{"workload": {"kind": "four_class", "lambda": 1.0}}"#,
        )
        .unwrap();
        assert_eq!(cfg.sim.event_schedule, None);
    }

    #[test]
    fn parses_custom_workload_with_scv() {
        let v = Value::parse(
            r#"{"kind":"custom","k":8,"classes":[
                {"need":1,"rate":1.0,"mean":2.0,"scv":4.0},
                {"need":8,"rate":0.1,"mean":1.0,"scv":0.25}]}"#,
        )
        .unwrap();
        let wl = parse_workload(&v).unwrap();
        assert_eq!(wl.num_classes(), 2);
        assert!((wl.classes[0].size.scv() - 4.0).abs() < 1e-9);
        assert!((wl.classes[1].size.scv() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn parses_rate_curve_and_rejects_invalid() {
        let v = Value::parse(
            r#"{"kind":"four_class","lambda":2.0,
                "rate_curve":{"kind":"diurnal","period":24.0,"amp":0.5,"phase":0.0}}"#,
        )
        .unwrap();
        let wl = parse_workload(&v).unwrap();
        assert_eq!(
            wl.rate_curve,
            crate::workload::RateCurve::Diurnal { period: 24.0, amp: 0.5, phase: 0.0 }
        );
        // Without the field the workload stays homogeneous.
        let plain = Value::parse(r#"{"kind":"four_class","lambda":2.0}"#).unwrap();
        assert_eq!(
            parse_workload(&plain).unwrap().rate_curve,
            crate::workload::RateCurve::Constant
        );
        // amp >= 1 would make the rate go nonpositive: rejected.
        let bad = Value::parse(
            r#"{"kind":"four_class","lambda":2.0,
                "rate_curve":{"kind":"diurnal","period":24.0,"amp":1.5}}"#,
        )
        .unwrap();
        assert!(parse_workload(&bad).is_err());
    }

    #[test]
    fn rejects_unknown_kind() {
        let v = Value::parse(r#"{"kind":"nope"}"#).unwrap();
        assert!(parse_workload(&v).is_err());
    }

    #[test]
    fn rejects_unknown_policy_name() {
        let err = ExperimentConfig::from_json(
            r#"{"workload": {"kind": "four_class", "lambda": 1.0},
                "policies": ["bogus"]}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown policy"));
    }

    #[test]
    fn parses_multires_and_custom_demand_arrays() {
        let v = Value::parse(r#"{"kind":"multires","k":16,"mem":64,"lambda":3.0}"#).unwrap();
        let wl = parse_workload(&v).unwrap();
        assert_eq!(wl.dims(), 2);
        assert_eq!(wl.k, 16);

        let v = Value::parse(
            r#"{"kind":"custom","k":8,"capacity":[8,32],"classes":[
                {"demand":[1,2],"rate":1.0,"mean":1.0},
                {"demand":[4,16],"rate":0.1,"mean":1.0}]}"#,
        )
        .unwrap();
        let wl = parse_workload(&v).unwrap();
        assert_eq!(wl.dims(), 2);
        assert_eq!(wl.classes[1].need(), 4);
        // Dimension mismatches and oversubscribed demands are errors.
        let bad = Value::parse(
            r#"{"kind":"custom","k":8,"classes":[{"demand":[1,2],"rate":1.0}]}"#,
        )
        .unwrap();
        assert!(parse_workload(&bad).is_err());
        let over = Value::parse(
            r#"{"kind":"custom","k":8,"capacity":[8,4],"classes":[
                {"demand":[1,5],"rate":1.0}]}"#,
        )
        .unwrap();
        assert!(parse_workload(&over).is_err());
    }
}
