//! Service-size distributions for job classes.
//!
//! The paper's experiments use exponential sizes; Appendix C checks
//! robustness under deterministic, Erlang (SCV < 1) and hyperexponential
//! (SCV > 1) sizes. All four are provided with exact closed-form moments
//! so the analysis layer and the config system (`scv` knob) can match a
//! distribution to a requested mean/SCV pair.

use crate::util::rng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub enum Dist {
    /// Exponential with rate `mu` (mean 1/mu, SCV 1).
    Exp { mu: f64 },
    /// Deterministic: point mass at `v` (SCV 0).
    Det { v: f64 },
    /// Erlang-k: sum of `k` i.i.d. Exp(rate) stages (mean k/rate, SCV 1/k).
    Erlang { k: u32, rate: f64 },
    /// Two-phase hyperexponential: Exp(mu1) w.p. `p`, else Exp(mu2)
    /// (SCV > 1 for distinct phases).
    Hyper2 { p: f64, mu1: f64, mu2: f64 },
}

impl Dist {
    /// Exponential with the given mean.
    pub fn exp_mean(mean: f64) -> Dist {
        assert!(mean > 0.0, "mean must be positive");
        Dist::Exp { mu: 1.0 / mean }
    }

    /// Balanced-means H2 fitted to (mean, scv) with scv > 1: the standard
    /// two-moment fit with p/mu1 = (1-p)/mu2,
    /// p = (1 + sqrt((scv-1)/(scv+1)))/2. Moments are matched exactly.
    pub fn hyper2_mean_scv(mean: f64, scv: f64) -> Dist {
        assert!(mean > 0.0, "mean must be positive");
        assert!(scv > 1.0, "hyperexponential fit needs scv > 1");
        let p = 0.5 * (1.0 + ((scv - 1.0) / (scv + 1.0)).sqrt());
        Dist::Hyper2 {
            p,
            mu1: 2.0 * p / mean,
            mu2: 2.0 * (1.0 - p) / mean,
        }
    }

    pub fn mean(&self) -> f64 {
        match *self {
            Dist::Exp { mu } => 1.0 / mu,
            Dist::Det { v } => v,
            Dist::Erlang { k, rate } => k as f64 / rate,
            Dist::Hyper2 { p, mu1, mu2 } => p / mu1 + (1.0 - p) / mu2,
        }
    }

    /// Second raw moment E[X²].
    pub fn second_moment(&self) -> f64 {
        match *self {
            Dist::Exp { mu } => 2.0 / (mu * mu),
            Dist::Det { v } => v * v,
            Dist::Erlang { k, rate } => (k as f64 * (k as f64 + 1.0)) / (rate * rate),
            Dist::Hyper2 { p, mu1, mu2 } => {
                2.0 * p / (mu1 * mu1) + 2.0 * (1.0 - p) / (mu2 * mu2)
            }
        }
    }

    pub fn variance(&self) -> f64 {
        let m = self.mean();
        self.second_moment() - m * m
    }

    /// Squared coefficient of variation Var[X]/E[X]².
    pub fn scv(&self) -> f64 {
        let m = self.mean();
        self.variance() / (m * m)
    }

    /// Draw one sample.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match *self {
            Dist::Exp { mu } => rng.exp(mu),
            Dist::Det { v } => v,
            Dist::Erlang { k, rate } => {
                let mut s = 0.0;
                for _ in 0..k {
                    s += rng.exp(rate);
                }
                s
            }
            Dist::Hyper2 { p, mu1, mu2 } => {
                if rng.chance(p) {
                    rng.exp(mu1)
                } else {
                    rng.exp(mu2)
                }
            }
        }
    }

    /// Fill `out` with i.i.d. samples — the chunk-fill twin of
    /// [`sample`](Dist::sample): one distribution dispatch per chunk
    /// instead of one per variate, with each family's inner loop kept
    /// tight ([`Rng::fill_exp`] for the exponential). Per-variate
    /// arithmetic and RNG draw order are identical to repeated
    /// `sample` calls, so scalar and chunked sampling paths are
    /// interchangeable bit-for-bit.
    pub fn fill(&self, rng: &mut Rng, out: &mut [f64]) {
        match *self {
            Dist::Exp { mu } => rng.fill_exp(mu, out),
            Dist::Det { v } => out.fill(v),
            Dist::Erlang { k, rate } => {
                for x in out.iter_mut() {
                    let mut s = 0.0;
                    for _ in 0..k {
                        s += rng.exp(rate);
                    }
                    *x = s;
                }
            }
            Dist::Hyper2 { p, mu1, mu2 } => {
                for x in out.iter_mut() {
                    *x = if rng.chance(p) {
                        rng.exp(mu1)
                    } else {
                        rng.exp(mu2)
                    };
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp_mean_roundtrips() {
        let d = Dist::exp_mean(2.5);
        assert!((d.mean() - 2.5).abs() < 1e-12);
        assert!((d.scv() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn hyper2_matches_mean_and_scv_exactly() {
        for (m, c) in [(1.0, 4.0), (2.0, 1.5), (0.5, 10.0)] {
            let d = Dist::hyper2_mean_scv(m, c);
            assert!((d.mean() - m).abs() < 1e-12, "mean {m} scv {c}");
            assert!((d.scv() - c).abs() < 1e-9, "mean {m} scv {c}: {}", d.scv());
        }
    }

    #[test]
    fn erlang_moments() {
        let d = Dist::Erlang { k: 4, rate: 4.0 }; // mean 1, scv 1/4
        assert!((d.mean() - 1.0).abs() < 1e-12);
        assert!((d.scv() - 0.25).abs() < 1e-12);
    }

    /// The chunk-fill path consumes the identical RNG stream as scalar
    /// sampling for every family — the contract that keeps the batched
    /// arrival source deterministic per (class, chunk).
    #[test]
    fn fill_bit_identical_to_scalar_sampling() {
        for d in [
            Dist::exp_mean(2.0),
            Dist::Det { v: 3.5 },
            Dist::Erlang { k: 3, rate: 1.5 },
            Dist::hyper2_mean_scv(2.0, 4.0),
        ] {
            let mut a = Rng::new(91);
            let mut b = Rng::new(91);
            let mut buf = [0.0; 64];
            d.fill(&mut a, &mut buf);
            for (i, &x) in buf.iter().enumerate() {
                assert_eq!(
                    x.to_bits(),
                    d.sample(&mut b).to_bits(),
                    "{d:?} variate {i}"
                );
            }
            assert_eq!(a.next_u64(), b.next_u64(), "{d:?} stream diverged");
        }
    }

    #[test]
    fn sample_means_converge() {
        let mut rng = Rng::new(17);
        for d in [
            Dist::exp_mean(2.0),
            Dist::Det { v: 2.0 },
            Dist::Erlang { k: 3, rate: 1.5 },
            Dist::hyper2_mean_scv(2.0, 4.0),
        ] {
            let n = 200_000;
            let mean: f64 = (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64;
            assert!(
                (mean - d.mean()).abs() / d.mean() < 0.05,
                "{d:?}: sample mean {mean} vs {}",
                d.mean()
            );
        }
    }
}
