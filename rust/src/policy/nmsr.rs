//! Nonpreemptive Markovian Service Rate (nMSR) policy, reimplemented from
//! its description in [13] (Chen, Grosof & Berg 2025): precompute one
//! saturated schedule per class (⌊k/need⌋ slots), and switch between
//! schedules according to a continuous-time Markov chain that is
//! *independent of queue lengths*. Because switching ignores the state,
//! capacity is wasted whenever the active schedule's class has too few
//! jobs — exactly the weakness Quickswap fixes.
//!
//! Chain: cycle over schedules with exponential holding times whose means
//! are proportional to each class's required capacity share
//! s_i ∝ λ_i/(⌊k/need_i⌋·μ_i) (plus uniform slack), scaled by a nominal
//! cycle length. When the timer fires the policy stops admitting, drains,
//! and activates the next schedule.

use crate::policy::{ClassId, Decision, PhaseLabel, Policy, SysView};
use crate::util::rng::Rng;
use crate::workload::Workload;

#[derive(Debug)]
pub struct Nmsr {
    order: Vec<ClassId>,
    /// Mean holding time per schedule (exponential).
    hold_mean: Vec<f64>,
    cur: usize,
    switching: bool,
    timer_armed: bool,
    rng: Rng,
    /// Incremental consult cache enabled (engine-driven).
    cache: bool,
}

impl Nmsr {
    /// `cycle` = nominal total cycle duration (sum of mean holds).
    pub fn new(wl: &Workload, cycle: f64) -> anyhow::Result<Nmsr> {
        anyhow::ensure!(cycle > 0.0, "cycle must be positive");
        let m = wl.num_classes();
        // Required capacity share per class under its own schedule.
        let mut share: Vec<f64> = wl
            .classes
            .iter()
            .map(|c| {
                let slots = (wl.k / c.need).max(1) as f64;
                c.rate * c.size.mean() / slots
            })
            .collect();
        let total: f64 = share.iter().sum();
        anyhow::ensure!(total > 0.0, "workload has no load");
        // Normalize and mix with uniform slack so every schedule gets
        // strictly positive time even for tiny classes.
        for s in share.iter_mut() {
            *s = 0.9 * (*s / total) + 0.1 / m as f64;
        }
        Ok(Nmsr {
            order: (0..m).collect(),
            hold_mean: share.iter().map(|s| s * cycle).collect(),
            cur: 0,
            switching: false,
            timer_armed: false,
            rng: Rng::new(0x6d73725f), // deterministic: policy-internal chain
            cache: false,
        })
    }

    fn admit_current(&self, sys: &SysView<'_>, out: &mut Decision) {
        let c = self.order[self.cur];
        let need = sys.needs[c];
        let slots = sys.k / need;
        let can = (slots.saturating_sub(sys.running[c])).min(sys.queued[c]) as usize;
        // Capacity check: other classes may still be draining.
        let mut free = sys.free();
        for id in sys.queued_iter(c).take(can) {
            if need > free {
                break;
            }
            out.admit.push(id);
            free -= need;
        }
    }
}

impl Policy for Nmsr {
    fn name(&self) -> String {
        "nMSR".into()
    }

    fn schedule(&mut self, sys: &SysView<'_>, out: &mut Decision) {
        // Consult-cache fast path. Once the modulating chain is armed,
        // a consult is a no-op (no admissions, no RNG draws, no state
        // change) exactly when: mid-switch with the previous schedule
        // still draining, or the active schedule cannot start a job
        // (slots full, nothing queued, or draining classes hold the
        // capacity). Unarmed and advance-the-chain consults fall
        // through — they draw from the policy RNG, so skipping them
        // would desynchronize cached and uncached trajectories.
        if self.cache && self.timer_armed {
            if self.switching {
                if sys.used > 0 {
                    return;
                }
            } else {
                // Fit check via the queue index's per-class counts.
                let idx = sys.queue_index();
                let c = self.order[self.cur];
                let need = sys.needs[c];
                let slots = sys.k / need;
                let can = slots.saturating_sub(idx.running_of(c)).min(idx.queued_of(c));
                if can == 0 || !idx.can_admit(c, sys.free()) {
                    return;
                }
            }
        }
        if !self.timer_armed {
            // First consult: arm the modulating chain.
            self.timer_armed = true;
            let hold = self.rng.exp(1.0 / self.hold_mean[self.cur]);
            out.set_timer = Some(sys.now + hold);
        }
        if self.switching {
            // Wait for the previous schedule to drain completely.
            if sys.used > 0 {
                return;
            }
            self.switching = false;
            self.cur = (self.cur + 1) % self.order.len();
            let hold = self.rng.exp(1.0 / self.hold_mean[self.cur]);
            out.set_timer = Some(sys.now + hold);
        }
        self.admit_current(sys, out);
    }

    fn on_timer(&mut self, _now: f64) {
        self.switching = true;
    }

    fn set_consult_cache(&mut self, enabled: bool) {
        self.cache = enabled;
    }

    fn phase_label(&self, _sys: &SysView<'_>) -> PhaseLabel {
        if self.switching {
            4
        } else {
            2
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Dist;
    use crate::policy::test_support::Harness;
    use crate::workload::{ClassSpec, Workload};

    fn wl() -> Workload {
        Workload::new(
            4,
            vec![
                ClassSpec::new(1, 1.0, Dist::exp_mean(1.0)),
                ClassSpec::new(4, 0.2, Dist::exp_mean(1.0)),
            ],
        )
    }

    #[test]
    fn serves_only_active_schedule() {
        let w = wl();
        let mut p = Nmsr::new(&w, 10.0).unwrap();
        let mut h = Harness::new(4, &[1, 4]);
        h.arrive(0, 0.0);
        h.arrive(1, 0.1);
        let adm = h.consult(&mut p);
        // Schedule 0 = class 0 (need 1): only lights admitted.
        assert_eq!(adm.len(), 1);
        assert_eq!(h.running[0], 1);
        assert_eq!(h.running[1], 0, "inactive schedule gets nothing");
    }

    #[test]
    fn switch_drains_then_advances() {
        let w = wl();
        let mut p = Nmsr::new(&w, 10.0).unwrap();
        let mut h = Harness::new(4, &[1, 4]);
        let l = h.arrive(0, 0.0);
        let hv = h.arrive(1, 0.1);
        h.consult(&mut p);
        // Chain fires: switching begins; no admissions until drain done.
        p.on_timer(1.0);
        h.arrive(0, 1.1);
        assert!(h.consult(&mut p).is_empty());
        h.complete(l, 2.0);
        // Drained → schedule advances to class 1 → heavy admitted.
        let adm = h.consult(&mut p);
        assert_eq!(adm, vec![hv]);
    }

    #[test]
    fn share_sums_reasonable() {
        let w = wl();
        let p = Nmsr::new(&w, 10.0).unwrap();
        let total: f64 = p.hold_mean.iter().sum();
        assert!((total - 10.0).abs() < 1e-9);
        assert!(p.hold_mean.iter().all(|&h| h > 0.0));
    }
}
