//! Sequential Markovian Service Rate (MSR-Seq), after the MSR framework
//! of [13] (Chen, Grosof & Berg): serve from a set of precomputed
//! saturated configurations — one per class, ⌊capacity/demand⌋ slots
//! under the vector model — and modulate which configuration is active
//! by a process that is *independent of queue lengths*. MSR-Seq is the
//! periodic member of the family: the configuration chain visits classes
//! in a fixed cyclic order and dwells on each for a **deterministic**
//! time proportional to the class's required capacity share (the
//! degenerate CTMC whose holding distributions are point masses).
//! Switches are nonpreemptive: admissions stop, the outgoing
//! configuration drains, then the next activates.
//!
//! Contrast [`crate::policy::Nmsr`] (exponential holding times over the
//! same cycle) and [`crate::policy::MsrRand`] (uniform random-walk jump
//! chain). All three waste capacity whenever the active configuration's
//! class runs out of jobs — the weakness Quickswap repairs.

use crate::policy::{ClassId, Decision, PhaseLabel, Policy, SysView};
use crate::workload::Workload;

#[derive(Debug)]
pub struct MsrSeq {
    order: Vec<ClassId>,
    /// Deterministic dwell time per configuration.
    hold: Vec<f64>,
    cur: usize,
    switching: bool,
    timer_armed: bool,
    /// Incremental consult cache enabled (engine-driven).
    cache: bool,
}

impl MsrSeq {
    /// `cycle` = total cycle duration (sum of the per-class dwells).
    pub fn new(wl: &Workload, cycle: f64) -> anyhow::Result<MsrSeq> {
        anyhow::ensure!(cycle > 0.0, "cycle must be positive");
        let m = wl.num_classes();
        // Required capacity share per class under its own configuration.
        let mut share: Vec<f64> = wl
            .classes
            .iter()
            .map(|c| {
                let slots = c.demand.max_pack(&wl.capacity).max(1) as f64;
                c.rate * c.size.mean() / slots
            })
            .collect();
        let total: f64 = share.iter().sum();
        anyhow::ensure!(total > 0.0, "workload has no load");
        // Normalize and mix with uniform slack so every configuration
        // gets strictly positive time even for tiny classes.
        for s in share.iter_mut() {
            *s = 0.9 * (*s / total) + 0.1 / m as f64;
        }
        Ok(MsrSeq {
            order: (0..m).collect(),
            hold: share.iter().map(|s| s * cycle).collect(),
            cur: 0,
            switching: false,
            timer_armed: false,
            cache: false,
        })
    }

    fn admit_current(&self, sys: &SysView<'_>, out: &mut Decision) {
        let c = self.order[self.cur];
        let slots = sys.demands[c].max_pack(&sys.capacity);
        let can = (slots.saturating_sub(sys.running[c])).min(sys.queued[c]) as usize;
        // Capacity check: other classes may still be draining.
        if sys.capacity.is_scalar() {
            let need = sys.needs[c];
            let mut free = sys.free();
            for id in sys.queued_iter(c).take(can) {
                if need > free {
                    break;
                }
                out.admit.push(id);
                free -= need;
            }
        } else {
            let demand = sys.demands[c];
            let mut free = sys.free_vec();
            for id in sys.queued_iter(c).take(can) {
                if !demand.fits_in(&free) {
                    break;
                }
                out.admit.push(id);
                free.sub_assign(&demand);
            }
        }
    }
}

impl Policy for MsrSeq {
    fn name(&self) -> String {
        "MSR-Seq".into()
    }

    fn schedule(&mut self, sys: &SysView<'_>, out: &mut Decision) {
        // Consult-cache fast path: once the modulating clock is armed, a
        // consult is a no-op exactly when mid-switch with the outgoing
        // configuration still draining, or when the active configuration
        // cannot start a job. The chain itself is deterministic (no RNG),
        // so skips can never desynchronize it.
        if self.cache && self.timer_armed {
            if self.switching {
                if sys.used > 0 {
                    return;
                }
            } else {
                let idx = sys.queue_index();
                let c = self.order[self.cur];
                let slots = sys.demands[c].max_pack(&sys.capacity);
                let can = slots.saturating_sub(idx.running_of(c)).min(idx.queued_of(c));
                if can == 0 || !idx.can_admit_vec(c, &sys.free_vec()) {
                    return;
                }
            }
        }
        if !self.timer_armed {
            // First consult: arm the modulating clock.
            self.timer_armed = true;
            out.set_timer = Some(sys.now + self.hold[self.cur]);
        }
        if self.switching {
            // Wait for the previous configuration to drain completely.
            if sys.used > 0 {
                return;
            }
            self.switching = false;
            self.cur = (self.cur + 1) % self.order.len();
            out.set_timer = Some(sys.now + self.hold[self.cur]);
        }
        self.admit_current(sys, out);
    }

    fn on_timer(&mut self, _now: f64) {
        self.switching = true;
    }

    fn set_consult_cache(&mut self, enabled: bool) {
        self.cache = enabled;
    }

    fn phase_label(&self, _sys: &SysView<'_>) -> PhaseLabel {
        if self.switching {
            4
        } else {
            2
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Dist;
    use crate::policy::test_support::Harness;
    use crate::workload::{ClassSpec, Workload};

    fn wl() -> Workload {
        Workload::new(
            4,
            vec![
                ClassSpec::new(1, 1.0, Dist::exp_mean(1.0)),
                ClassSpec::new(4, 0.2, Dist::exp_mean(1.0)),
            ],
        )
    }

    #[test]
    fn serves_only_active_configuration() {
        let w = wl();
        let mut p = MsrSeq::new(&w, 10.0).unwrap();
        let mut h = Harness::new(4, &[1, 4]);
        h.arrive(0, 0.0);
        h.arrive(1, 0.1);
        let adm = h.consult(&mut p);
        // Configuration 0 = class 0 (need 1): only lights admitted.
        assert_eq!(adm.len(), 1);
        assert_eq!(h.running[0], 1);
        assert_eq!(h.running[1], 0, "inactive configuration gets nothing");
    }

    #[test]
    fn switch_drains_then_advances() {
        let w = wl();
        let mut p = MsrSeq::new(&w, 10.0).unwrap();
        let mut h = Harness::new(4, &[1, 4]);
        let l = h.arrive(0, 0.0);
        let hv = h.arrive(1, 0.1);
        h.consult(&mut p);
        // Clock fires: switching begins; no admissions until drain done.
        p.on_timer(1.0);
        h.arrive(0, 1.1);
        assert!(h.consult(&mut p).is_empty());
        h.complete(l, 2.0);
        // Drained → configuration advances to class 1 → heavy admitted.
        let adm = h.consult(&mut p);
        assert_eq!(adm, vec![hv]);
    }

    #[test]
    fn dwells_sum_to_cycle() {
        let w = wl();
        let p = MsrSeq::new(&w, 10.0).unwrap();
        let total: f64 = p.hold.iter().sum();
        assert!((total - 10.0).abs() < 1e-9);
        assert!(p.hold.iter().all(|&h| h > 0.0));
    }

    /// On a 2-resource workload the configuration size comes from vector
    /// packing: class demands (2, 8) into capacity (8, 16) → 2 slots,
    /// bound by the memory dimension, not the 4 the servers alone allow.
    #[test]
    fn vector_configuration_uses_max_pack() {
        use crate::workload::ResourceVec;
        let w = Workload::with_capacity(
            ResourceVec::new(&[8, 16]),
            vec![ClassSpec::with_demand(
                ResourceVec::new(&[2, 8]),
                1.0,
                Dist::exp_mean(1.0),
            )],
        );
        let mut p = MsrSeq::new(&w, 10.0).unwrap();
        let mut h = Harness::with_capacity(w.capacity, &w.demands());
        for i in 0..4 {
            h.arrive(0, i as f64 * 0.01);
        }
        let adm = h.consult(&mut p);
        assert_eq!(adm.len(), 2, "memory dimension must cap the configuration");
        assert_eq!(h.used(), 4);
    }
}
