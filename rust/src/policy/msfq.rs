//! Most Servers First with Quickswap (§4.2) — the paper's contribution
//! for the one-or-all setting.
//!
//! MSFQ is MSF plus a threshold ℓ: while serving light (1-server) jobs,
//! as soon as the number of lights in service would drop to ℓ, the policy
//! stops admitting lights, drains the ones already running (phase 4), and
//! switches to heavy (k-server) jobs. ℓ = 0 recovers MSF exactly; the
//! paper's recommended heuristic is ℓ = k − 1.
//!
//! Phases (paper labels, exposed for the Fig-4 tracker):
//!   1 — serving heavy jobs until none remain,
//!   2 — serving lights with all k servers busy (n₁ ≥ k),
//!   3 — serving lights with n₁ < k, still admitting,
//!   4 — draining: lights in service complete, no admissions.

use crate::policy::{ClassId, Decision, PhaseLabel, Policy, SysView};
use crate::workload::Workload;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Mode {
    /// Serving heavy jobs (or idle).
    Heavy,
    /// Serving light jobs, admissions allowed (paper phases 2/3).
    Light,
    /// Quickswap triggered: draining in-service lights (paper phase 4).
    Drain,
}

#[derive(Debug)]
pub struct Msfq {
    pub ell: u32,
    light: ClassId,
    heavy: ClassId,
    mode: Mode,
    /// Incremental consult cache enabled (engine-driven).
    cache: bool,
}

impl Msfq {
    /// `ell` ∈ [0, k−1]. The workload must be one-or-all: exactly one
    /// class with need 1 and one with need k.
    pub fn new(wl: &Workload, ell: u32) -> anyhow::Result<Msfq> {
        anyhow::ensure!(
            ell < wl.k,
            "quickswap threshold ell={ell} must be < k={}",
            wl.k
        );
        anyhow::ensure!(
            wl.dims() == 1,
            "MSFQ requires the scalar (servers-only) model, got {} resource dimensions",
            wl.dims()
        );
        let mut light = None;
        let mut heavy = None;
        for (c, cl) in wl.classes.iter().enumerate() {
            if cl.need() == 1 {
                anyhow::ensure!(light.is_none(), "multiple light classes");
                light = Some(c);
            } else if cl.need() == wl.k {
                anyhow::ensure!(heavy.is_none(), "multiple heavy classes");
                heavy = Some(c);
            } else {
                anyhow::bail!(
                    "MSFQ requires a one-or-all workload; class {c} needs {} of {}",
                    cl.need(),
                    wl.k
                );
            }
        }
        Ok(Msfq {
            ell,
            light: light.ok_or_else(|| anyhow::anyhow!("no light (need-1) class"))?,
            heavy: heavy.ok_or_else(|| anyhow::anyhow!("no heavy (need-k) class"))?,
            mode: Mode::Heavy,
            cache: false,
        })
    }

    /// Decide the next mode at a switch point (no job of either class in
    /// service), admitting as appropriate. Mirrors the zero-length-phase
    /// cascade of §4.2: phase 1 ends only when no heavies remain; then
    /// lights are served (phase 2/3) if n₁ > ℓ, else drained (phase 4).
    fn dispatch(&mut self, sys: &SysView<'_>, out: &mut Decision) {
        if sys.in_system(self.heavy) > 0 {
            self.mode = Mode::Heavy;
            if let Some(id) = sys.queued_head(self.heavy) {
                out.admit.push(id);
            }
            return;
        }
        let n1 = sys.in_system(self.light);
        if n1 == 0 {
            self.mode = Mode::Heavy; // idle
        } else if n1 > self.ell {
            self.mode = Mode::Light;
            self.admit_lights(sys, out);
        } else {
            // All n₁ ≤ ℓ lights enter service, then the door closes.
            self.mode = Mode::Drain;
            for id in sys.queued_iter(self.light) {
                out.admit.push(id);
            }
        }
    }

    fn admit_lights(&self, sys: &SysView<'_>, out: &mut Decision) {
        let free = sys.free() as usize;
        let take = free.min(sys.queued[self.light] as usize);
        for id in sys.queued_iter(self.light).take(take) {
            out.admit.push(id);
        }
    }
}

impl Policy for Msfq {
    fn name(&self) -> String {
        format!("MSFQ(ell={})", self.ell)
    }

    fn schedule(&mut self, sys: &SysView<'_>, out: &mut Decision) {
        let (l, h) = (self.light, self.heavy);
        // Consult-cache fast path. Away from a switch point, `schedule`
        // is a no-op in Heavy mode (a heavy holds all k servers) and in
        // Drain mode (admissions closed); in Light mode it is a no-op
        // exactly when the quickswap trigger cannot fire (n₁ > ℓ) and no
        // light can start (the queue index's fit check: nothing queued
        // or no free server). Every other case admits or transitions, so
        // it falls through to the full consult — making skips
        // bit-identical to the uncached policy.
        if self.cache && (sys.running[l] > 0 || sys.running[h] > 0) {
            match self.mode {
                Mode::Heavy | Mode::Drain => return,
                Mode::Light => {
                    if sys.in_system(l) > self.ell && !sys.queue_index().can_admit(l, sys.free()) {
                        return;
                    }
                }
            }
        }
        if sys.running[l] == 0 && sys.running[h] == 0 {
            // Switch point: previous phase fully drained (or idle).
            self.dispatch(sys, out);
            return;
        }
        match self.mode {
            Mode::Heavy => {
                // A heavy occupies all k servers; nothing to add.
            }
            Mode::Light => {
                if sys.in_system(l) <= self.ell {
                    // Quickswap trigger: in-service lights ≤ ℓ.
                    self.mode = Mode::Drain;
                } else {
                    self.admit_lights(sys, out);
                }
            }
            Mode::Drain => {
                // No admissions while draining.
            }
        }
    }

    fn set_consult_cache(&mut self, enabled: bool) {
        self.cache = enabled;
    }

    fn phase_label(&self, sys: &SysView<'_>) -> PhaseLabel {
        match self.mode {
            Mode::Heavy => {
                if sys.running[self.heavy] > 0 {
                    1
                } else {
                    0 // idle
                }
            }
            Mode::Light => {
                if sys.in_system(self.light) >= sys.k {
                    2
                } else {
                    3
                }
            }
            Mode::Drain => 4,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Dist;
    use crate::policy::test_support::Harness;
    use crate::workload::{ClassSpec, Workload};

    fn wl(k: u32) -> Workload {
        Workload::new(
            k,
            vec![
                ClassSpec::new(1, 1.0, Dist::exp_mean(1.0)),
                ClassSpec::new(k, 0.1, Dist::exp_mean(1.0)),
            ],
        )
    }

    #[test]
    fn rejects_bad_workloads() {
        let w = Workload::new(
            8,
            vec![
                ClassSpec::new(1, 1.0, Dist::exp_mean(1.0)),
                ClassSpec::new(4, 1.0, Dist::exp_mean(1.0)),
            ],
        );
        assert!(Msfq::new(&w, 3).is_err());
        assert!(Msfq::new(&wl(8), 8).is_err()); // ell must be < k
        assert!(Msfq::new(&wl(8), 7).is_ok());
    }

    /// The quickswap: serving lights, once n₁ ≤ ℓ no more lights enter
    /// service even though servers are idle; heavies go next.
    #[test]
    fn drains_at_threshold_and_switches_to_heavy() {
        let k = 4;
        let mut h = Harness::new(k, &[1, k]);
        let mut p = Msfq::new(&wl(k), 2).unwrap();
        // 5 lights arrive; 4 enter service (phase 2: n1=5 ≥ k).
        let ids: Vec<_> = (0..5).map(|i| h.arrive(0, i as f64 * 0.01)).collect();
        let adm = h.consult(&mut p);
        assert_eq!(adm.len(), 4);
        // A heavy arrives and must wait.
        let heavy = h.arrive(1, 0.5);
        assert!(h.consult(&mut p).is_empty());
        // One light completes: n1 = 4 > ℓ=2 → the 5th light is admitted.
        h.complete(ids[0], 1.0);
        assert_eq!(h.consult(&mut p), vec![ids[4]]);
        // Two more complete: n1 = 2 ≤ ℓ → drain begins; new lights queue.
        h.complete(ids[1], 1.1);
        h.consult(&mut p);
        h.complete(ids[2], 1.2);
        assert!(h.consult(&mut p).is_empty());
        let late_light = h.arrive(0, 1.25);
        assert!(h.consult(&mut p).is_empty(), "no admissions in drain");
        // Remaining two lights finish → heavy admitted (phase 1).
        h.complete(ids[3], 1.3);
        assert!(h.consult(&mut p).is_empty());
        h.complete(ids[4], 1.4);
        assert_eq!(h.consult(&mut p), vec![heavy]);
        // Heavy done → the queued light (n1=1 ≤ ℓ) enters via drain mode.
        h.complete(heavy, 2.0);
        assert_eq!(h.consult(&mut p), vec![late_light]);
        assert_eq!(p.phase_label(&h.view()), 4);
    }

    /// ℓ=0 must reproduce MSF's exhaustive light service.
    #[test]
    fn ell_zero_is_exhaustive() {
        let k = 3;
        let mut h = Harness::new(k, &[1, k]);
        let mut p = Msfq::new(&wl(k), 0).unwrap();
        let l1 = h.arrive(0, 0.0);
        assert_eq!(h.consult(&mut p), vec![l1]);
        let hv = h.arrive(1, 0.1);
        let l2 = h.arrive(0, 0.2);
        // With ℓ=0 lights keep being admitted while any light is in system.
        assert_eq!(h.consult(&mut p), vec![l2]);
        h.complete(l1, 1.0);
        h.complete(l2, 1.1);
        assert_eq!(h.consult(&mut p), vec![hv]);
    }

    /// A light arriving to an empty system under ℓ≥1 enters service in
    /// drain mode: later lights must wait for it (§4.2 as defined).
    #[test]
    fn empty_system_light_enters_drain() {
        let k = 4;
        let mut h = Harness::new(k, &[1, k]);
        let mut p = Msfq::new(&wl(k), k - 1).unwrap();
        let a = h.arrive(0, 0.0);
        assert_eq!(h.consult(&mut p), vec![a]);
        assert_eq!(p.phase_label(&h.view()), 4);
        let b = h.arrive(0, 0.1);
        assert!(h.consult(&mut p).is_empty());
        h.complete(a, 1.0);
        assert_eq!(h.consult(&mut p), vec![b]);
    }
}
