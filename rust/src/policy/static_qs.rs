//! Static Quickswap (§4.3): cycle through job classes in a fixed order
//! (descending server need). For the current class i:
//!
//! * **Working phase** — serve class-i exclusively with up to ⌊k/i⌋ jobs
//!   in service, until the number of idle servers exceeds k − ℓ
//!   (equivalently: busy servers < ℓ).
//! * **Draining phase** — no admissions; once the in-service class-i jobs
//!   complete, move to the next class in the cycle.
//!
//! ℓ is a *server-count* threshold (the MSFQ analogue: for the light class
//! in a one-or-all workload, busy servers = jobs in service).

use crate::policy::{ClassId, Decision, PhaseLabel, Policy, SysView};
use crate::workload::Workload;

#[derive(Debug)]
pub struct StaticQuickswap {
    /// Busy-server threshold: quickswap to draining when `used < ell`.
    pub ell: u32,
    /// Visit order (descending need).
    cycle: Vec<ClassId>,
    cur: usize,
    draining: bool,
    /// Incremental consult cache enabled (engine-driven).
    cache: bool,
}

impl StaticQuickswap {
    pub fn new(wl: &Workload, ell: u32) -> StaticQuickswap {
        let mut cycle: Vec<ClassId> = (0..wl.num_classes()).collect();
        let needs = wl.needs();
        cycle.sort_by_key(|&c| std::cmp::Reverse(needs[c]));
        StaticQuickswap {
            ell: ell.min(wl.k),
            cycle,
            cur: 0,
            draining: false,
            cache: false,
        }
    }

    /// Current class being served/drained.
    pub fn current_class(&self) -> ClassId {
        self.cycle[self.cur]
    }
}

impl Policy for StaticQuickswap {
    fn name(&self) -> String {
        format!("StaticQS(ell={})", self.ell)
    }

    fn schedule(&mut self, sys: &SysView<'_>, out: &mut Decision) {
        // Consult-cache fast path: replicate the loop's first-iteration
        // exit conditions that provably neither admit nor mutate state —
        // mid-drain with jobs still in service, or working fully loaded.
        // Fit checks read the queue index's per-class counts. Every
        // other case (top-up possible, drain finished, quickswap
        // condition met) falls through to the full consult.
        if self.cache {
            let idx = sys.queue_index();
            let c = self.cycle[self.cur];
            let need = sys.needs[c];
            let slots = sys.demands[c].max_pack(&sys.capacity);
            if self.draining {
                if idx.running_of(c) > 0 {
                    return;
                }
            } else if (slots - idx.running_of(c)).min(idx.queued_of(c)) == 0 {
                let busy = idx.running_of(c) * need;
                let cap = (need * slots).min(self.ell + 1);
                if busy >= cap {
                    return;
                }
            }
        }
        // At most one full tour of the cycle per consult.
        for _ in 0..=self.cycle.len() {
            let c = self.cycle[self.cur];
            let need = sys.needs[c];
            // Exclusive service means `slots` copies of the class's whole
            // demand vector always fit; at d=1 this is the scalar ⌊k/need⌋.
            let slots = sys.demands[c].max_pack(&sys.capacity);

            if self.draining {
                if sys.running[c] > 0 {
                    return; // still draining
                }
                self.draining = false;
                self.cur = (self.cur + 1) % self.cycle.len();
                continue;
            }

            // Working phase: top up class-c slots.
            let can = (slots - sys.running[c]).min(sys.queued[c]) as usize;
            if can > 0 {
                for id in sys.queued_iter(c).take(can) {
                    out.admit.push(id);
                }
                // Admissions will retrigger schedule(); evaluate the
                // quickswap condition on the next consult.
                return;
            }
            // Quickswap trigger: idle servers exceed k − ℓ. The
            // threshold is capped at the class's achievable busy level
            // need·⌊k/need⌋ — otherwise classes whose need does not
            // divide k would drain even with a full queue (they can
            // never exceed ℓ = k−1 busy servers).
            let busy = sys.running[c] * need;
            let cap = (need * slots).min(self.ell + 1);
            if busy < cap {
                if sys.running[c] > 0 {
                    self.draining = true;
                    return;
                }
                // Nothing in service: skip straight past the drain.
                self.cur = (self.cur + 1) % self.cycle.len();
                // If the whole system is empty, park here.
                if sys.total_in_system() == 0 {
                    return;
                }
                continue;
            }
            return; // working, fully loaded
        }
    }

    fn set_consult_cache(&mut self, enabled: bool) {
        self.cache = enabled;
    }

    fn phase_label(&self, sys: &SysView<'_>) -> PhaseLabel {
        let c = self.cycle[self.cur];
        if self.draining {
            4
        } else if sys.running[c] > 0 {
            2
        } else {
            0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Dist;
    use crate::policy::test_support::Harness;
    use crate::workload::{ClassSpec, Workload};

    fn wl4() -> Workload {
        Workload::four_class(1.0) // k=15, needs {1,3,5,15}
    }

    #[test]
    fn serves_one_class_exclusively() {
        let wl = wl4();
        let mut p = StaticQuickswap::new(&wl, wl.k - 1);
        let mut h = Harness::new(15, &[1, 3, 5, 15]);
        // Queue jobs of class 1 (need 3) and class 0 (need 1).
        for i in 0..6 {
            h.arrive(1, i as f64 * 0.01);
        }
        for i in 0..4 {
            h.arrive(0, 0.1 + i as f64 * 0.01);
        }
        let adm = h.consult(&mut p);
        // Cycle starts at need-15, empty → need-5, empty → need-3: 5 slots.
        assert_eq!(adm.len(), 5);
        assert_eq!(h.running[1], 5);
        assert_eq!(h.running[0], 0, "exclusive service");
        assert_eq!(h.used(), 15);
    }

    #[test]
    fn drains_then_advances() {
        let wl = wl4();
        let mut p = StaticQuickswap::new(&wl, wl.k - 1);
        let mut h = Harness::new(15, &[1, 3, 5, 15]);
        let a = h.arrive(1, 0.0); // need 3
        let b = h.arrive(1, 0.01);
        for i in 0..3 {
            h.arrive(0, 0.1 + i as f64 * 0.01);
        }
        let adm = h.consult(&mut p);
        assert_eq!(adm.len(), 2); // both need-3 jobs in service, busy=6 < 14 → drain
        assert!(h.consult(&mut p).is_empty(), "draining: no admissions");
        h.complete(a, 1.0);
        assert!(h.consult(&mut p).is_empty());
        h.complete(b, 1.1);
        // Drain over → next classes in cycle → class need-1 gets served.
        let adm = h.consult(&mut p);
        assert_eq!(adm.len(), 3);
        assert_eq!(h.running[0], 3);
    }

    #[test]
    fn full_queue_keeps_working() {
        let wl = wl4();
        let mut p = StaticQuickswap::new(&wl, wl.k - 1);
        let mut h = Harness::new(15, &[1, 3, 5, 15]);
        let ids: Vec<_> = (0..8).map(|i| h.arrive(1, i as f64 * 0.01)).collect();
        h.consult(&mut p); // 5 in service
        h.complete(ids[0], 1.0);
        // Replacement admitted immediately: still working, busy stays 15.
        let adm = h.consult(&mut p);
        assert_eq!(adm.len(), 1);
        assert_eq!(h.used(), 15);
    }
}
