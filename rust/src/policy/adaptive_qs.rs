//! Adaptive Quickswap (§4.4): admit in MSF order during the working
//! phase; quickswap to a draining phase when some class is waiting but
//! not served while every in-service class has no waiting jobs (i.e.,
//! continuing to backfill would only starve the waiting class). During
//! the drain, only the largest-need queued job may enter; once it does,
//! return to the working phase.
//!
//! Consult cache: both halves of the working-phase skip predicate come
//! **exactly** from the driver-maintained [`crate::sim::QueueIndex`] —
//! "no queued job fits" is the O(log C) `min_queued_need` query and the
//! §4.4 trigger is an O(1) read of the starving/backlogged class
//! counters. Unlike the former conservative watermark, the predicate
//! needs no reset on swap epochs and stays exact across admission
//! batches; the drain-phase target lookup (largest-need queued class)
//! is an O(log C) Fenwick descent instead of an O(C) scan.

use crate::policy::msf::msf_admit;
use crate::policy::{Decision, PhaseLabel, Policy, SysView};

#[derive(Debug, Default)]
pub struct AdaptiveQuickswap {
    draining: bool,
    /// Incremental consult cache enabled (engine-driven).
    cache: bool,
}

impl AdaptiveQuickswap {
    pub fn new() -> AdaptiveQuickswap {
        AdaptiveQuickswap::default()
    }

    /// §4.4 trigger: ∃ class queued with nothing in service, and every
    /// class in service has an empty queue. O(1) from the index counters
    /// (debug builds cross-check the full scan).
    fn trigger(&self, sys: &SysView<'_>) -> bool {
        let fast = sys.swap_trigger();
        #[cfg(debug_assertions)]
        {
            let mut starving = false;
            let mut backlogged = false;
            for c in 0..sys.needs.len() {
                starving |= sys.queued[c] > 0 && sys.running[c] == 0;
                backlogged |= sys.running[c] > 0 && sys.queued[c] > 0;
            }
            debug_assert_eq!(fast, starving && !backlogged, "trigger counters diverged");
        }
        fast
    }
}

impl Policy for AdaptiveQuickswap {
    fn name(&self) -> String {
        "AdaptiveQS".into()
    }

    fn schedule(&mut self, sys: &SysView<'_>, out: &mut Decision) {
        if self.draining {
            // Only the largest-need queued job may enter service.
            match sys.queue_index().max_queued_class() {
                None => {
                    self.draining = false; // queue empty: resume working
                }
                Some(c) => {
                    if sys.demand_fits(c) {
                        if let Some(id) = sys.queued_head(c) {
                            out.admit.push(id);
                            self.draining = false;
                        }
                    }
                }
            }
            return;
        }
        // Working phase. Fast path: if no queued job can fit (exact, via
        // the index) and the drain trigger cannot fire, the full consult
        // would admit nothing and change nothing — skip it.
        if self.cache
            && !sys.queue_index().queued_demand_fits(&sys.free_vec())
            && !self.trigger(sys)
        {
            return;
        }
        // MSF-order admission.
        let admitted = msf_admit(sys, out);
        if admitted == 0 && self.trigger(sys) {
            self.draining = true;
        }
    }

    fn set_consult_cache(&mut self, enabled: bool) {
        self.cache = enabled;
    }

    fn phase_label(&self, _sys: &SysView<'_>) -> PhaseLabel {
        if self.draining {
            4
        } else {
            2
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::test_support::Harness;

    /// Lights keep the system busy; a heavy waits. Once no light is
    /// queued, the trigger fires and lights stop entering, letting the
    /// heavy in after the drain.
    #[test]
    fn quickswaps_to_starving_heavy() {
        let k = 4;
        let mut h = Harness::new(k, &[1, 4]);
        let mut p = AdaptiveQuickswap::new();
        let lights: Vec<_> = (0..4).map(|i| h.arrive(0, i as f64 * 0.01)).collect();
        assert_eq!(h.consult(&mut p).len(), 4);
        let heavy = h.arrive(1, 0.5);
        let extra = h.arrive(0, 0.6);
        // A light completes; `extra` is queued so no trigger yet: MSF
        // admission puts `extra` straight in.
        h.complete(lights[0], 1.0);
        assert_eq!(h.consult(&mut p), vec![extra]);
        // Next completion: no lights queued, heavy starving → drain.
        h.complete(lights[1], 1.1);
        assert!(h.consult(&mut p).is_empty());
        assert!(p.draining);
        // New light arrivals must NOT enter during the drain.
        let late = h.arrive(0, 1.2);
        assert!(h.consult(&mut p).is_empty());
        h.complete(lights[2], 1.3);
        h.consult(&mut p);
        h.complete(lights[3], 1.4);
        h.consult(&mut p);
        h.complete(extra, 1.5);
        // All free: heavy enters, drain ends (it may re-arm because the
        // late light is now the starving class behind the full system).
        let adm = h.consult(&mut p);
        assert_eq!(adm[0], heavy);
        // After the heavy completes, the late light resumes service.
        h.complete(heavy, 2.5);
        assert_eq!(h.consult(&mut p), vec![late]);
    }

    /// With needs that don't divide k, AdaptiveQS backfills smaller
    /// classes in the working phase (unlike StaticQS exclusivity).
    #[test]
    fn backfills_mixed_classes() {
        let mut h = Harness::new(8, &[1, 5]);
        let mut p = AdaptiveQuickswap::new();
        h.arrive(1, 0.0);
        for i in 0..4 {
            h.arrive(0, 0.1 + i as f64 * 0.01);
        }
        h.consult(&mut p);
        assert_eq!(h.used(), 8); // 5 + 3×1
        assert_eq!(h.running[0], 3);
    }
}
