//! Adaptive Quickswap (§4.4): admit in MSF order during the working
//! phase; quickswap to a draining phase when some class is waiting but
//! not served while every in-service class has no waiting jobs (i.e.,
//! continuing to backfill would only starve the waiting class). During
//! the drain, only the largest-need queued job may enter; once it does,
//! return to the working phase.
//!
//! Consult cache: the working phase reuses MSF's [`ConsultWatermark`],
//! with the extra condition that the §4.4 trigger must not fire (a
//! trigger flip is an observable state change); the drain phase is
//! already O(classes) with no allocation and consults in full.

use crate::policy::msf::msf_admit;
use crate::policy::{ClassId, ConsultWatermark, Decision, PhaseLabel, Policy, SysView};

#[derive(Debug, Default)]
pub struct AdaptiveQuickswap {
    draining: bool,
    by_need: Vec<usize>,
    /// Consult cache: skip while free capacity is below the watermark
    /// (and the drain trigger cannot fire).
    watermark: ConsultWatermark,
}

impl AdaptiveQuickswap {
    pub fn new() -> AdaptiveQuickswap {
        AdaptiveQuickswap::default()
    }

    fn ensure_order(&mut self, needs: &[u32]) {
        if self.by_need.len() != needs.len() {
            let mut idx: Vec<usize> = (0..needs.len()).collect();
            idx.sort_by_key(|&c| std::cmp::Reverse(needs[c]));
            self.by_need = idx;
        }
    }

    /// §4.4 trigger: ∃ class queued with nothing in service, and every
    /// class in service has an empty queue.
    fn trigger(&self, sys: &SysView<'_>) -> bool {
        let mut starving = false;
        for c in 0..sys.needs.len() {
            if sys.queued[c] > 0 && sys.running[c] == 0 {
                starving = true;
            }
            if sys.running[c] > 0 && sys.queued[c] > 0 {
                return false; // an in-service class still has backlog
            }
        }
        starving
    }
}

impl Policy for AdaptiveQuickswap {
    fn name(&self) -> String {
        "AdaptiveQS".into()
    }

    fn schedule(&mut self, sys: &SysView<'_>, out: &mut Decision) {
        self.ensure_order(sys.needs);
        if self.draining {
            // Only the largest-need queued job may enter service.
            let target = self
                .by_need
                .iter()
                .copied()
                .find(|&c| sys.queued[c] > 0);
            match target {
                None => {
                    self.draining = false; // queue empty: resume working
                }
                Some(c) => {
                    if sys.needs[c] <= sys.free() {
                        if let Some(id) = sys.queued_head(c) {
                            out.admit.push(id);
                            self.draining = false;
                        }
                    }
                }
            }
            return;
        }
        // Working phase. Fast path: if no queued job can fit (watermark)
        // and the drain trigger cannot fire, the full consult would
        // admit nothing and change nothing — skip it.
        if self.watermark.blocks(sys.free()) && !self.trigger(sys) {
            return;
        }
        // MSF-order admission.
        let (admitted, min_need) = msf_admit(sys, &self.by_need, out);
        self.watermark.set(if admitted == 0 { min_need } else { 0 });
        if admitted == 0 && self.trigger(sys) {
            self.draining = true;
        }
    }

    fn on_arrival(&mut self, _class: ClassId, need: u32) {
        self.watermark.observe_arrival(need);
    }

    fn on_swap_epoch(&mut self) {
        self.watermark.reset();
    }

    fn set_consult_cache(&mut self, enabled: bool) {
        self.watermark.set_enabled(enabled);
    }

    fn phase_label(&self, _sys: &SysView<'_>) -> PhaseLabel {
        if self.draining {
            4
        } else {
            2
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::test_support::Harness;

    /// Lights keep the system busy; a heavy waits. Once no light is
    /// queued, the trigger fires and lights stop entering, letting the
    /// heavy in after the drain.
    #[test]
    fn quickswaps_to_starving_heavy() {
        let k = 4;
        let mut h = Harness::new(k, &[1, 4]);
        let mut p = AdaptiveQuickswap::new();
        let lights: Vec<_> = (0..4).map(|i| h.arrive(0, i as f64 * 0.01)).collect();
        assert_eq!(h.consult(&mut p).len(), 4);
        let heavy = h.arrive(1, 0.5);
        let extra = h.arrive(0, 0.6);
        // A light completes; `extra` is queued so no trigger yet: MSF
        // admission puts `extra` straight in.
        h.complete(lights[0], 1.0);
        assert_eq!(h.consult(&mut p), vec![extra]);
        // Next completion: no lights queued, heavy starving → drain.
        h.complete(lights[1], 1.1);
        assert!(h.consult(&mut p).is_empty());
        assert!(p.draining);
        // New light arrivals must NOT enter during the drain.
        let late = h.arrive(0, 1.2);
        assert!(h.consult(&mut p).is_empty());
        h.complete(lights[2], 1.3);
        h.consult(&mut p);
        h.complete(lights[3], 1.4);
        h.consult(&mut p);
        h.complete(extra, 1.5);
        // All free: heavy enters, drain ends (it may re-arm because the
        // late light is now the starving class behind the full system).
        let adm = h.consult(&mut p);
        assert_eq!(adm[0], heavy);
        // After the heavy completes, the late light resumes service.
        h.complete(heavy, 2.5);
        assert_eq!(h.consult(&mut p), vec![late]);
    }

    /// With needs that don't divide k, AdaptiveQS backfills smaller
    /// classes in the working phase (unlike StaticQS exclusivity).
    #[test]
    fn backfills_mixed_classes() {
        let mut h = Harness::new(8, &[1, 5]);
        let mut p = AdaptiveQuickswap::new();
        h.arrive(1, 0.0);
        for i in 0..4 {
            h.arrive(0, 0.1 + i as f64 * 0.01);
        }
        h.consult(&mut p);
        assert_eq!(h.used(), 8); // 5 + 3×1
        assert_eq!(h.running[0], 3);
    }
}
