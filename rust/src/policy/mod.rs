//! Scheduling policies for the multiserver-job (MSJ) model.
//!
//! A [`Policy`] observes the system through a [`SysView`] after every
//! event (arrival, departure, policy timer) and emits a [`Decision`]:
//! which queued jobs to admit (and, for preemptive policies, which running
//! jobs to preempt). The engine enforces feasibility (`Σ need ≤ k`) and
//! non-preemption for policies that declare themselves non-preemptive.
//!
//! ## Incremental consults (the consult cache)
//!
//! At ρ → 1 most consults admit nothing: the system is full and the
//! event merely shuffles the queue. Policies therefore support an
//! *incremental consult protocol*: the driver (engine, harness) notifies
//! them of state deltas between consults ([`Policy::on_arrival`],
//! [`Policy::on_departure`], [`Policy::on_swap_epoch`]), and a policy
//! with its consult cache enabled ([`Policy::set_consult_cache`]) may
//! short-circuit `schedule` calls it can *prove* are no-ops — via the
//! driver-maintained [`crate::sim::QueueIndex`] (exact O(log C)
//! "smallest queued need" and O(1) trigger counters), an O(1) phase
//! predicate ("draining: admissions closed until the in-service class
//! empties"), or the arrival-order prefix version for ServerFilling.
//! Because the driver applies every delta to the index before the
//! post-event consult, the index-backed skip predicates are **exact**,
//! not conservative — they survive admission batches without resets.
//!
//! The contract is strict: a cached policy must produce **bit-identical
//! decisions and internal state transitions** to its uncached self on
//! every event sequence. Skips are only legal when the full consult
//! would have admitted nothing, preempted nothing, set no timer, and
//! mutated no observable policy state (mode flags included, since they
//! feed `phase_label`). This is enforced by differential property tests
//! (`tests/prop_consult_cache.rs`) and engine-level goldens
//! (`tests/integration_replication.rs`).
//!
//! The cache is off by default on bare-constructed policies (unit tests
//! drive policies without delta notifications); the engine enables it
//! per run from [`SimConfig`](crate::sim::SimConfig) / the
//! `QS_NO_CONSULT_CACHE` environment escape hatch, because the engine is
//! the layer that guarantees the notification hooks fire.

pub mod adaptive_qs;
pub mod fcfs;
pub mod test_support;
pub mod first_fit;
pub mod msf;
pub mod msfq;
pub mod nmsr;
pub mod server_filling;
pub mod static_qs;

pub use adaptive_qs::AdaptiveQuickswap;
pub use fcfs::Fcfs;
pub use first_fit::FirstFit;
pub use msf::Msf;
pub use msfq::Msfq;
pub use nmsr::Nmsr;
pub use server_filling::ServerFilling;
pub use static_qs::StaticQuickswap;

use crate::workload::Workload;

pub type ClassId = usize;
pub type JobId = u64;

/// Paper phase labels used by the phase-duration tracker (Fig 4).
/// 0 = untracked/other; 1..=4 = the MSFQ phases of §4.2.
pub type PhaseLabel = u8;

/// What a policy can see. Borrow-backed by the engine; all accessors are
/// O(1) except the arrival-order iterator and `queued_iter`, which are
/// O(items visited) — both walk intrusive lists of live jobs only (no
/// tombstone filtering).
pub struct SysView<'a> {
    pub now: f64,
    /// Total servers.
    pub k: u32,
    /// Busy servers.
    pub used: u32,
    /// Server need per class.
    pub needs: &'a [u32],
    /// Jobs waiting (not in service) per class.
    pub queued: &'a [u32],
    /// Jobs currently in service per class.
    pub running: &'a [u32],
    /// Job table (lookup class/need/state by id).
    pub jobs: &'a crate::sim::job::JobTable,
    /// Per-class intrusive FIFO of waiting jobs (front = oldest).
    pub(crate) fifos: &'a crate::sim::job::ClassFifos,
    /// Indexed queue summary (see [`crate::sim::QueueIndex`]): Fenwick
    /// tree over need-ranked classes plus O(1) trigger counters, kept
    /// exact by the driver on every arrival/admission/departure.
    pub(crate) index: &'a crate::sim::job::QueueIndex,
}

impl SysView<'_> {
    #[inline]
    pub fn free(&self) -> u32 {
        self.k - self.used
    }

    /// The indexed queue summary — O(log C) fit queries and O(1)
    /// aggregate counters maintained by the driver.
    #[inline]
    pub fn queue_index(&self) -> &crate::sim::job::QueueIndex {
        self.index
    }

    /// Smallest need among queued jobs (`u32::MAX` when none): the exact
    /// "no consult can admit below this free capacity" watermark.
    #[inline]
    pub fn min_queued_need(&self) -> u32 {
        self.index.min_queued_need()
    }

    /// Need of the head-of-line job — the *oldest queued* job in
    /// arrival order — or `u32::MAX` when nothing waits. O(1) from the
    /// JobTable's incrementally-maintained HoL cursor: the
    /// arrival-order-aware query the class-ranked queue index cannot
    /// answer, and the exact FCFS skip predicate (FCFS admits something
    /// iff its head of line fits).
    #[inline]
    pub fn hol_queued_need(&self) -> u32 {
        match self.jobs.hol_queued_slot() {
            Some(slot) => self.jobs.need(self.jobs.id_at(slot)),
            None => u32::MAX,
        }
    }

    /// Visit **queued** jobs in arrival order starting at the head of
    /// line; `f` returns false to stop. Skips the in-service prefix
    /// entirely — O(queued visited), not O(jobs in system).
    pub fn for_each_queued_in_arrival_order(&self, f: &mut dyn FnMut(JobId, ClassId) -> bool) {
        self.jobs.for_each_queued_from_hol(f);
    }

    /// AdaptiveQS's §4.4 quickswap trigger, O(1) from the index.
    #[inline]
    pub fn swap_trigger(&self) -> bool {
        self.index.swap_trigger()
    }

    /// Total jobs in system for class `c`.
    #[inline]
    pub fn in_system(&self, c: ClassId) -> u32 {
        self.queued[c] + self.running[c]
    }

    /// Total jobs in system across classes — O(1) from the index.
    pub fn total_in_system(&self) -> u32 {
        self.index.total_live()
    }

    /// Oldest waiting job of class `c` (front of the class FIFO).
    #[inline]
    pub fn queued_head(&self, c: ClassId) -> Option<JobId> {
        self.fifos.head_slot(c).map(|s| self.jobs.id_at(s))
    }

    /// Front-to-back (oldest-first) iterator over the waiting jobs of
    /// class `c`. Allocation-free: walks the intrusive class FIFO.
    /// (Replaces the former `Vec`-allocating `queued_front`.)
    #[inline]
    pub fn queued_iter(&self, c: ClassId) -> impl Iterator<Item = JobId> + '_ {
        let jobs = self.jobs;
        self.fifos.iter(c).map(move |s| jobs.id_at(s))
    }

    /// Visit jobs in arrival order; `f` returns false to stop early.
    /// Includes running jobs (`running` flag) so prefix-based policies
    /// (ServerFilling) can reason over the full arrival order.
    pub fn for_each_in_arrival_order(&self, f: &mut dyn FnMut(JobId, ClassId, bool) -> bool) {
        self.jobs.for_each_in_order(f);
    }

    /// Number of distinct classes with at least one waiting job.
    pub fn classes_with_queue(&self) -> usize {
        self.queued.iter().filter(|&&q| q > 0).count()
    }
}

/// Scheduling decision. Buffers are reused across events by the engine.
#[derive(Default, Debug)]
pub struct Decision {
    /// Queued job ids to put into service now (validated by the engine).
    pub admit: Vec<JobId>,
    /// Running job ids to preempt (only honored for preemptive policies).
    pub preempt: Vec<JobId>,
    /// Absolute time at which the policy wants `on_timer` to fire.
    /// Replaces any previously-set timer.
    pub set_timer: Option<f64>,
}

impl Decision {
    pub fn clear(&mut self) {
        self.admit.clear();
        self.preempt.clear();
        self.set_timer = None;
    }
}

/// A scheduling policy.
///
/// Beyond `schedule`, policies participate in the incremental consult
/// protocol (see the module docs): the driver reports queue/service
/// deltas through `on_arrival` / `on_departure` / `on_swap_epoch`, and a
/// policy whose consult cache is enabled may use that information to
/// short-circuit provably no-op consults. All protocol methods default
/// to no-ops, so a policy that ignores them is simply always consulted
/// in full.
pub trait Policy {
    fn name(&self) -> String;

    /// Called after every event until it produces an empty decision.
    fn schedule(&mut self, sys: &SysView<'_>, out: &mut Decision);

    /// Called when the timer requested via `Decision::set_timer` fires
    /// (immediately before `schedule`).
    fn on_timer(&mut self, _now: f64) {}

    /// A job of `class` (needing `need` servers) joined the waiting
    /// queue. Called after the system state reflects the arrival and
    /// before the post-event consult.
    fn on_arrival(&mut self, _class: ClassId, _need: u32) {}

    /// A job of `class` completed, releasing `need` servers. Called
    /// after the system state reflects the departure and before the
    /// post-event consult.
    fn on_departure(&mut self, _class: ClassId, _need: u32) {}

    /// The driver applied this policy's own (non-empty) decision: the
    /// service set swapped via admissions and/or preemptions. Policies
    /// whose cached watermarks are invalidated by their own admissions
    /// reset them here; policies that can prove their decisions reach a
    /// fixed point (ServerFilling) deliberately keep their cache warm.
    fn on_swap_epoch(&mut self) {}

    /// Enable/disable the incremental consult cache. Off by default;
    /// the engine switches it on per run (the driver must guarantee the
    /// `on_*` delta notifications fire, which bare `Harness` usage does
    /// not). Toggling must leave the policy in a consistent
    /// always-consult state.
    fn set_consult_cache(&mut self, _enabled: bool) {}

    /// Preemptive policies may return running jobs in `Decision::preempt`.
    fn is_preemptive(&self) -> bool {
        false
    }

    /// Current paper-phase label for the phase-duration tracker.
    fn phase_label(&self, _sys: &SysView<'_>) -> PhaseLabel {
        0
    }
}

/// Process-wide default for the consult cache: enabled unless the
/// `QS_NO_CONSULT_CACHE` escape hatch is set (to anything but `0`/empty),
/// which forces the full per-event recompute everywhere — the
/// differential-testing baseline.
pub fn consult_cache_enabled() -> bool {
    !matches!(std::env::var("QS_NO_CONSULT_CACHE"), Ok(v) if !v.is_empty() && v != "0")
}

/// Construct a policy by name (CLI / config entry point).
///
/// Names: `fcfs`, `first-fit`, `msf`, `msfq[:ell]`, `static-qs[:ell]`,
/// `adaptive-qs`, `nmsr[:cycle]`, `server-filling`.
pub fn by_name(name: &str, wl: &Workload) -> anyhow::Result<Box<dyn Policy + Send>> {
    let (base, arg) = match name.split_once(':') {
        Some((b, a)) => (b, Some(a)),
        None => (name, None),
    };
    let parse_u32 = |a: Option<&str>, d: u32| -> anyhow::Result<u32> {
        Ok(match a {
            Some(s) => s.parse()?,
            None => d,
        })
    };
    Ok(match base {
        "fcfs" => Box::new(Fcfs::new()),
        "first-fit" | "firstfit" | "ff" => Box::new(FirstFit::new()),
        "msf" => Box::new(Msf::new()),
        "msfq" => {
            let ell = parse_u32(arg, wl.k.saturating_sub(1))?;
            Box::new(Msfq::new(wl, ell)?)
        }
        "static-qs" | "staticqs" => {
            let ell = parse_u32(arg, wl.k.saturating_sub(1))?;
            Box::new(StaticQuickswap::new(wl, ell))
        }
        "adaptive-qs" | "adaptiveqs" => Box::new(AdaptiveQuickswap::new()),
        "nmsr" => {
            let cycle: f64 = match arg {
                Some(s) => s.parse()?,
                None => 50.0,
            };
            Box::new(Nmsr::new(wl, cycle)?)
        }
        "server-filling" | "serverfilling" | "sf" => Box::new(ServerFilling::new()),
        _ => anyhow::bail!("unknown policy '{name}'"),
    })
}

/// All nonpreemptive policy names used across the paper's figures.
pub const NONPREEMPTIVE: &[&str] = &[
    "fcfs",
    "first-fit",
    "msf",
    "msfq",
    "static-qs",
    "adaptive-qs",
    "nmsr",
];
