//! Scheduling policies for the multiserver-job (MSJ) model.
//!
//! A [`Policy`] observes the system through a [`SysView`] after every
//! event (arrival, departure, policy timer) and emits a [`Decision`]:
//! which queued jobs to admit (and, for preemptive policies, which running
//! jobs to preempt). The engine enforces feasibility (`Σ need ≤ k`) and
//! non-preemption for policies that declare themselves non-preemptive.

pub mod adaptive_qs;
pub mod fcfs;
pub mod test_support;
pub mod first_fit;
pub mod msf;
pub mod msfq;
pub mod nmsr;
pub mod server_filling;
pub mod static_qs;

pub use adaptive_qs::AdaptiveQuickswap;
pub use fcfs::Fcfs;
pub use first_fit::FirstFit;
pub use msf::Msf;
pub use msfq::Msfq;
pub use nmsr::Nmsr;
pub use server_filling::ServerFilling;
pub use static_qs::StaticQuickswap;

use crate::workload::Workload;

pub type ClassId = usize;
pub type JobId = u64;

/// Paper phase labels used by the phase-duration tracker (Fig 4).
/// 0 = untracked/other; 1..=4 = the MSFQ phases of §4.2.
pub type PhaseLabel = u8;

/// What a policy can see. Borrow-backed by the engine; all accessors are
/// O(1) except the arrival-order iterator and `queued_front`, which are
/// O(items visited) — both walk intrusive lists of live jobs only (no
/// tombstone filtering).
pub struct SysView<'a> {
    pub now: f64,
    /// Total servers.
    pub k: u32,
    /// Busy servers.
    pub used: u32,
    /// Server need per class.
    pub needs: &'a [u32],
    /// Jobs waiting (not in service) per class.
    pub queued: &'a [u32],
    /// Jobs currently in service per class.
    pub running: &'a [u32],
    /// Job table (lookup class/need/state by id).
    pub jobs: &'a crate::sim::job::JobTable,
    /// Per-class intrusive FIFO of waiting jobs (front = oldest).
    pub(crate) fifos: &'a crate::sim::job::ClassFifos,
}

impl<'a> SysView<'a> {
    #[inline]
    pub fn free(&self) -> u32 {
        self.k - self.used
    }

    /// Total jobs in system for class `c`.
    #[inline]
    pub fn in_system(&self, c: ClassId) -> u32 {
        self.queued[c] + self.running[c]
    }

    /// Total jobs in system across classes.
    pub fn total_in_system(&self) -> u32 {
        (0..self.needs.len()).map(|c| self.in_system(c)).sum()
    }

    /// Oldest waiting job of class `c` (front of the class FIFO).
    #[inline]
    pub fn queued_head(&self, c: ClassId) -> Option<JobId> {
        self.fifos.head_slot(c).map(|s| self.jobs.id_at(s))
    }

    /// First `n` oldest waiting jobs of class `c`.
    pub fn queued_front(&self, c: ClassId, n: usize) -> Vec<JobId> {
        self.fifos
            .iter(c)
            .take(n)
            .map(|s| self.jobs.id_at(s))
            .collect()
    }

    /// Visit jobs in arrival order; `f` returns false to stop early.
    /// Includes running jobs (`running` flag) so prefix-based policies
    /// (ServerFilling) can reason over the full arrival order.
    pub fn for_each_in_arrival_order(&self, f: &mut dyn FnMut(JobId, ClassId, bool) -> bool) {
        self.jobs.for_each_in_order(f);
    }

    /// Number of distinct classes with at least one waiting job.
    pub fn classes_with_queue(&self) -> usize {
        self.queued.iter().filter(|&&q| q > 0).count()
    }
}

/// Scheduling decision. Buffers are reused across events by the engine.
#[derive(Default, Debug)]
pub struct Decision {
    /// Queued job ids to put into service now (validated by the engine).
    pub admit: Vec<JobId>,
    /// Running job ids to preempt (only honored for preemptive policies).
    pub preempt: Vec<JobId>,
    /// Absolute time at which the policy wants `on_timer` to fire.
    /// Replaces any previously-set timer.
    pub set_timer: Option<f64>,
}

impl Decision {
    pub fn clear(&mut self) {
        self.admit.clear();
        self.preempt.clear();
        self.set_timer = None;
    }
}

/// A scheduling policy.
pub trait Policy {
    fn name(&self) -> String;

    /// Called after every event until it produces an empty decision.
    fn schedule(&mut self, sys: &SysView<'_>, out: &mut Decision);

    /// Called when the timer requested via `Decision::set_timer` fires
    /// (immediately before `schedule`).
    fn on_timer(&mut self, _now: f64) {}

    /// Preemptive policies may return running jobs in `Decision::preempt`.
    fn is_preemptive(&self) -> bool {
        false
    }

    /// Current paper-phase label for the phase-duration tracker.
    fn phase_label(&self, _sys: &SysView<'_>) -> PhaseLabel {
        0
    }
}

/// Construct a policy by name (CLI / config entry point).
///
/// Names: `fcfs`, `first-fit`, `msf`, `msfq[:ell]`, `static-qs[:ell]`,
/// `adaptive-qs`, `nmsr[:cycle]`, `server-filling`.
pub fn by_name(name: &str, wl: &Workload) -> anyhow::Result<Box<dyn Policy + Send>> {
    let (base, arg) = match name.split_once(':') {
        Some((b, a)) => (b, Some(a)),
        None => (name, None),
    };
    let parse_u32 = |a: Option<&str>, d: u32| -> anyhow::Result<u32> {
        Ok(match a {
            Some(s) => s.parse()?,
            None => d,
        })
    };
    Ok(match base {
        "fcfs" => Box::new(Fcfs::new()),
        "first-fit" | "firstfit" | "ff" => Box::new(FirstFit::new()),
        "msf" => Box::new(Msf::new()),
        "msfq" => {
            let ell = parse_u32(arg, wl.k.saturating_sub(1))?;
            Box::new(Msfq::new(wl, ell)?)
        }
        "static-qs" | "staticqs" => {
            let ell = parse_u32(arg, wl.k.saturating_sub(1))?;
            Box::new(StaticQuickswap::new(wl, ell))
        }
        "adaptive-qs" | "adaptiveqs" => Box::new(AdaptiveQuickswap::new()),
        "nmsr" => {
            let cycle: f64 = match arg {
                Some(s) => s.parse()?,
                None => 50.0,
            };
            Box::new(Nmsr::new(wl, cycle)?)
        }
        "server-filling" | "serverfilling" | "sf" => Box::new(ServerFilling::new()),
        _ => anyhow::bail!("unknown policy '{name}'"),
    })
}

/// All nonpreemptive policy names used across the paper's figures.
pub const NONPREEMPTIVE: &[&str] = &["fcfs", "first-fit", "msf", "msfq", "static-qs", "adaptive-qs", "nmsr"];
