//! Scheduling policies for the multiserver-job (MSJ) model.
//!
//! A [`Policy`] observes the system through a [`SysView`] after every
//! event (arrival, departure, policy timer) and emits a [`Decision`]:
//! which queued jobs to admit (and, for preemptive policies, which running
//! jobs to preempt). The engine enforces feasibility (`Σ need ≤ k`) and
//! non-preemption for policies that declare themselves non-preemptive.
//!
//! ## Incremental consults (the consult cache)
//!
//! At ρ → 1 most consults admit nothing: the system is full and the
//! event merely shuffles the queue. Policies therefore support an
//! *incremental consult protocol*: the driver (engine, harness) notifies
//! them of state deltas between consults ([`Policy::on_arrival`],
//! [`Policy::on_departure`], [`Policy::on_swap_epoch`]), and a policy
//! with its consult cache enabled ([`Policy::set_consult_cache`]) may
//! short-circuit `schedule` calls it can *prove* are no-ops — via the
//! driver-maintained [`crate::sim::QueueIndex`] (exact O(log C)
//! "smallest queued need" and O(1) trigger counters), an O(1) phase
//! predicate ("draining: admissions closed until the in-service class
//! empties"), or the arrival-order prefix version for ServerFilling.
//! Because the driver applies every delta to the index before the
//! post-event consult, the index-backed skip predicates are **exact**,
//! not conservative — they survive admission batches without resets.
//!
//! The contract is strict: a cached policy must produce **bit-identical
//! decisions and internal state transitions** to its uncached self on
//! every event sequence. Skips are only legal when the full consult
//! would have admitted nothing, preempted nothing, set no timer, and
//! mutated no observable policy state (mode flags included, since they
//! feed `phase_label`). This is enforced by differential property tests
//! (`tests/prop_consult_cache.rs`) and engine-level goldens
//! (`tests/integration_replication.rs`).
//!
//! The cache is off by default on bare-constructed policies (unit tests
//! drive policies without delta notifications); the engine enables it
//! per run from [`SimConfig`](crate::sim::SimConfig) / the
//! `QS_NO_CONSULT_CACHE` environment escape hatch, because the engine is
//! the layer that guarantees the notification hooks fire.

pub mod adaptive_qs;
pub mod fcfs;
pub mod test_support;
pub mod first_fit;
pub mod msf;
pub mod msfq;
pub mod msr_rand;
pub mod msr_seq;
pub mod nmsr;
pub mod server_filling;
pub mod static_qs;

pub use adaptive_qs::AdaptiveQuickswap;
pub use fcfs::Fcfs;
pub use first_fit::FirstFit;
pub use msf::Msf;
pub use msfq::Msfq;
pub use msr_rand::MsrRand;
pub use msr_seq::MsrSeq;
pub use nmsr::Nmsr;
pub use server_filling::ServerFilling;
pub use static_qs::StaticQuickswap;

use crate::workload::{ResourceVec, Workload};
use std::fmt;
use std::str::FromStr;

pub type ClassId = usize;
pub type JobId = u64;

/// Paper phase labels used by the phase-duration tracker (Fig 4).
/// 0 = untracked/other; 1..=4 = the MSFQ phases of §4.2.
pub type PhaseLabel = u8;

/// What a policy can see. Borrow-backed by the engine; all accessors are
/// O(1) except the arrival-order iterator and `queued_iter`, which are
/// O(items visited) — both walk intrusive lists of live jobs only (no
/// tombstone filtering).
pub struct SysView<'a> {
    pub now: f64,
    /// Total servers (dimension 0 of `capacity`).
    pub k: u32,
    /// Busy servers (dimension 0 of `used_vec`).
    pub used: u32,
    /// Full resource capacity vector (d=1 in the scalar model).
    pub capacity: ResourceVec,
    /// Per-dimension resource usage.
    pub used_vec: ResourceVec,
    /// Server need per class (dimension-0 projection of `demands`).
    pub needs: &'a [u32],
    /// Full demand vector per class.
    pub demands: &'a [ResourceVec],
    /// Jobs waiting (not in service) per class.
    pub queued: &'a [u32],
    /// Jobs currently in service per class.
    pub running: &'a [u32],
    /// Job table (lookup class/need/state by id).
    pub jobs: &'a crate::sim::job::JobTable,
    /// Per-class intrusive FIFO of waiting jobs (front = oldest).
    pub(crate) fifos: &'a crate::sim::job::ClassFifos,
    /// Indexed queue summary (see [`crate::sim::QueueIndex`]): Fenwick
    /// tree over need-ranked classes plus O(1) trigger counters, kept
    /// exact by the driver on every arrival/admission/departure.
    pub(crate) index: &'a crate::sim::job::QueueIndex,
}

impl SysView<'_> {
    #[inline]
    pub fn free(&self) -> u32 {
        self.k - self.used
    }

    /// Resource dimensions (1 = the scalar model).
    #[inline]
    pub fn dims(&self) -> usize {
        self.capacity.dims()
    }

    /// Free capacity per dimension (dimension 0 equals [`Self::free`]).
    #[inline]
    pub fn free_vec(&self) -> ResourceVec {
        self.capacity.saturating_sub(&self.used_vec)
    }

    /// Class `c`'s full demand vector.
    #[inline]
    pub fn demand(&self, c: ClassId) -> ResourceVec {
        self.demands[c]
    }

    /// True iff class `c`'s whole demand vector fits in the free
    /// capacity — the vector admission predicate. At d=1 this is exactly
    /// the scalar `needs[c] <= free()` comparison.
    #[inline]
    pub fn demand_fits(&self, c: ClassId) -> bool {
        if self.capacity.is_scalar() {
            return self.needs[c] <= self.free();
        }
        self.demands[c].fits_in(&self.free_vec())
    }

    /// The indexed queue summary — O(log C) fit queries and O(1)
    /// aggregate counters maintained by the driver.
    #[inline]
    pub fn queue_index(&self) -> &crate::sim::job::QueueIndex {
        self.index
    }

    /// Smallest need among queued jobs (`u32::MAX` when none): the exact
    /// "no consult can admit below this free capacity" watermark.
    #[inline]
    pub fn min_queued_need(&self) -> u32 {
        self.index.min_queued_need()
    }

    /// Need of the head-of-line job — the *oldest queued* job in
    /// arrival order — or `u32::MAX` when nothing waits. O(1) from the
    /// JobTable's incrementally-maintained HoL cursor: the
    /// arrival-order-aware query the class-ranked queue index cannot
    /// answer, and the exact FCFS skip predicate (FCFS admits something
    /// iff its head of line fits).
    #[inline]
    pub fn hol_queued_need(&self) -> u32 {
        match self.jobs.hol_queued_slot() {
            Some(slot) => self.jobs.need(self.jobs.id_at(slot)),
            None => u32::MAX,
        }
    }

    /// True iff the head-of-line job's whole demand vector fits in the
    /// free capacity — the exact FCFS admit predicate under the vector
    /// model (at d=1 exactly `hol_queued_need() <= free()`).
    #[inline]
    pub fn hol_demand_fits(&self) -> bool {
        if self.capacity.is_scalar() {
            return self.hol_queued_need() <= self.free();
        }
        match self.jobs.hol_queued_slot() {
            Some(slot) => {
                let c = self.jobs.class(self.jobs.id_at(slot));
                self.demands[c].fits_in(&self.free_vec())
            }
            None => false,
        }
    }

    /// Visit **queued** jobs in arrival order starting at the head of
    /// line; `f` returns false to stop. Skips the in-service prefix
    /// entirely — O(queued visited), not O(jobs in system).
    pub fn for_each_queued_in_arrival_order(&self, f: &mut dyn FnMut(JobId, ClassId) -> bool) {
        self.jobs.for_each_queued_from_hol(f);
    }

    /// AdaptiveQS's §4.4 quickswap trigger, O(1) from the index.
    #[inline]
    pub fn swap_trigger(&self) -> bool {
        self.index.swap_trigger()
    }

    /// Total jobs in system for class `c`.
    #[inline]
    pub fn in_system(&self, c: ClassId) -> u32 {
        self.queued[c] + self.running[c]
    }

    /// Total jobs in system across classes — O(1) from the index.
    pub fn total_in_system(&self) -> u32 {
        self.index.total_live()
    }

    /// Oldest waiting job of class `c` (front of the class FIFO).
    #[inline]
    pub fn queued_head(&self, c: ClassId) -> Option<JobId> {
        self.fifos.head_slot(c).map(|s| self.jobs.id_at(s))
    }

    /// Front-to-back (oldest-first) iterator over the waiting jobs of
    /// class `c`. Allocation-free: walks the intrusive class FIFO.
    /// (Replaces the former `Vec`-allocating `queued_front`.)
    #[inline]
    pub fn queued_iter(&self, c: ClassId) -> impl Iterator<Item = JobId> + '_ {
        let jobs = self.jobs;
        self.fifos.iter(c).map(move |s| jobs.id_at(s))
    }

    /// Visit jobs in arrival order; `f` returns false to stop early.
    /// Includes running jobs (`running` flag) so prefix-based policies
    /// (ServerFilling) can reason over the full arrival order.
    pub fn for_each_in_arrival_order(&self, f: &mut dyn FnMut(JobId, ClassId, bool) -> bool) {
        self.jobs.for_each_in_order(f);
    }

    /// Number of distinct classes with at least one waiting job.
    pub fn classes_with_queue(&self) -> usize {
        self.queued.iter().filter(|&&q| q > 0).count()
    }
}

/// Scheduling decision. Buffers are reused across events by the engine.
#[derive(Default, Debug)]
pub struct Decision {
    /// Queued job ids to put into service now (validated by the engine).
    pub admit: Vec<JobId>,
    /// Running job ids to preempt (only honored for preemptive policies).
    pub preempt: Vec<JobId>,
    /// Absolute time at which the policy wants `on_timer` to fire.
    /// Replaces any previously-set timer.
    pub set_timer: Option<f64>,
}

impl Decision {
    pub fn clear(&mut self) {
        self.admit.clear();
        self.preempt.clear();
        self.set_timer = None;
    }
}

/// A scheduling policy.
///
/// Beyond `schedule`, policies participate in the incremental consult
/// protocol (see the module docs): the driver reports queue/service
/// deltas through `on_arrival` / `on_departure` / `on_swap_epoch`, and a
/// policy whose consult cache is enabled may use that information to
/// short-circuit provably no-op consults. All protocol methods default
/// to no-ops, so a policy that ignores them is simply always consulted
/// in full.
pub trait Policy {
    fn name(&self) -> String;

    /// Called after every event until it produces an empty decision.
    fn schedule(&mut self, sys: &SysView<'_>, out: &mut Decision);

    /// Called when the timer requested via `Decision::set_timer` fires
    /// (immediately before `schedule`).
    fn on_timer(&mut self, _now: f64) {}

    /// A job of `class` (needing `need` servers) joined the waiting
    /// queue. Called after the system state reflects the arrival and
    /// before the post-event consult.
    fn on_arrival(&mut self, _class: ClassId, _need: u32) {}

    /// A job of `class` completed, releasing `need` servers. Called
    /// after the system state reflects the departure and before the
    /// post-event consult.
    fn on_departure(&mut self, _class: ClassId, _need: u32) {}

    /// The driver applied this policy's own (non-empty) decision: the
    /// service set swapped via admissions and/or preemptions. Policies
    /// whose cached watermarks are invalidated by their own admissions
    /// reset them here; policies that can prove their decisions reach a
    /// fixed point (ServerFilling) deliberately keep their cache warm.
    fn on_swap_epoch(&mut self) {}

    /// Enable/disable the incremental consult cache. Off by default;
    /// the engine switches it on per run (the driver must guarantee the
    /// `on_*` delta notifications fire, which bare `Harness` usage does
    /// not). Toggling must leave the policy in a consistent
    /// always-consult state.
    fn set_consult_cache(&mut self, _enabled: bool) {}

    /// Preemptive policies may return running jobs in `Decision::preempt`.
    fn is_preemptive(&self) -> bool {
        false
    }

    /// Current paper-phase label for the phase-duration tracker.
    fn phase_label(&self, _sys: &SysView<'_>) -> PhaseLabel {
        0
    }
}

/// Process-wide default for the consult cache: enabled unless the
/// `QS_NO_CONSULT_CACHE` escape hatch is set (to anything but `0`/empty),
/// which forces the full per-event recompute everywhere — the
/// differential-testing baseline.
pub fn consult_cache_enabled() -> bool {
    !matches!(std::env::var("QS_NO_CONSULT_CACHE"), Ok(v) if !v.is_empty() && v != "0")
}

/// Typed policy identifier — the parse/Display twin of
/// [`crate::experiments::FigureId`], replacing the former stringly
/// `by_name(&str)` surface. A `PolicyId` carries the policy's optional
/// argument (quickswap threshold ℓ, MSR cycle length), parses every
/// spelling the CLI ever accepted, and `Display`s back to the canonical
/// string (`"msfq:31"`, `"nmsr"`), which is what travels in
/// [`SweepSpec`](crate::sweep::SweepSpec) wire JSON and CSV policy
/// columns — so typed specs stay byte-compatible with stringly ones.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PolicyId {
    Fcfs,
    FirstFit,
    Msf,
    /// MSFQ with an optional threshold ℓ (default k−1 at build time).
    Msfq(Option<u32>),
    /// Static Quickswap with an optional threshold ℓ (default k−1).
    StaticQs(Option<u32>),
    AdaptiveQs,
    /// Nonpreemptive MSR with an optional cycle length (default 50.0).
    Nmsr(Option<f64>),
    ServerFilling,
    /// Markovian Service Rate, deterministic-cycle chain (arXiv
    /// 2412.08915) with an optional mean cycle length (default 50.0).
    MsrSeq(Option<f64>),
    /// Markovian Service Rate, uniform random-walk chain with an
    /// optional mean cycle length (default 50.0).
    MsrRand(Option<f64>),
}

impl PolicyId {
    /// Canonical names of every policy, as listed in unknown-name
    /// errors and the CLI help.
    pub const ALL: &'static [&'static str] = &[
        "fcfs",
        "first-fit",
        "msf",
        "msfq[:ell]",
        "static-qs[:ell]",
        "adaptive-qs",
        "nmsr[:cycle]",
        "server-filling",
        "msr-seq[:cycle]",
        "msr-rand[:cycle]",
    ];

    /// Parse a policy name with optional `:arg`, accepting the historic
    /// aliases (`ff`, `serverfilling`, ...). Unknown names error with
    /// the full list of valid policies.
    pub fn parse(s: &str) -> anyhow::Result<PolicyId> {
        let s = s.trim();
        let (base, arg) = match s.split_once(':') {
            Some((b, a)) => (b, Some(a)),
            None => (s, None),
        };
        let u32_arg = |what: &str| -> anyhow::Result<Option<u32>> {
            arg.map(|a| {
                a.parse::<u32>()
                    .map_err(|_| anyhow::anyhow!("bad {what} '{a}' in policy '{s}'"))
            })
            .transpose()
        };
        let f64_arg = |what: &str| -> anyhow::Result<Option<f64>> {
            arg.map(|a| {
                a.parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("bad {what} '{a}' in policy '{s}'"))
            })
            .transpose()
        };
        let no_arg = |id: PolicyId| -> anyhow::Result<PolicyId> {
            match arg {
                Some(a) => anyhow::bail!("policy '{base}' takes no argument, got ':{a}'"),
                None => Ok(id),
            }
        };
        match base {
            "fcfs" => no_arg(PolicyId::Fcfs),
            "first-fit" | "firstfit" | "ff" => no_arg(PolicyId::FirstFit),
            "msf" => no_arg(PolicyId::Msf),
            "msfq" => Ok(PolicyId::Msfq(u32_arg("threshold")?)),
            "static-qs" | "staticqs" => Ok(PolicyId::StaticQs(u32_arg("threshold")?)),
            "adaptive-qs" | "adaptiveqs" => no_arg(PolicyId::AdaptiveQs),
            "nmsr" => Ok(PolicyId::Nmsr(f64_arg("cycle")?)),
            "server-filling" | "serverfilling" | "sf" => no_arg(PolicyId::ServerFilling),
            "msr-seq" | "msrseq" => Ok(PolicyId::MsrSeq(f64_arg("cycle")?)),
            "msr-rand" | "msrrand" => Ok(PolicyId::MsrRand(f64_arg("cycle")?)),
            other => anyhow::bail!(
                "unknown policy '{other}' (valid: {})",
                PolicyId::ALL.join(", ")
            ),
        }
    }

    /// Canonical base name (no argument).
    pub fn base(&self) -> &'static str {
        match self {
            PolicyId::Fcfs => "fcfs",
            PolicyId::FirstFit => "first-fit",
            PolicyId::Msf => "msf",
            PolicyId::Msfq(_) => "msfq",
            PolicyId::StaticQs(_) => "static-qs",
            PolicyId::AdaptiveQs => "adaptive-qs",
            PolicyId::Nmsr(_) => "nmsr",
            PolicyId::ServerFilling => "server-filling",
            PolicyId::MsrSeq(_) => "msr-seq",
            PolicyId::MsrRand(_) => "msr-rand",
        }
    }

    /// `MSFQ`-style suffix for per-policy environment overrides,
    /// mirroring [`crate::experiments::FigureId::env_suffix`].
    pub fn env_suffix(&self) -> String {
        self.base().to_uppercase().replace('-', "_")
    }

    /// True for the policies the paper classifies as nonpreemptive.
    pub fn is_nonpreemptive(&self) -> bool {
        !matches!(self, PolicyId::ServerFilling)
    }
}

/// Canonical spelling: base name plus `:arg` when one was given —
/// `"msfq:31"` round-trips through parse/Display unchanged.
impl fmt::Display for PolicyId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.base())?;
        match self {
            PolicyId::Msfq(Some(ell)) | PolicyId::StaticQs(Some(ell)) => write!(f, ":{ell}"),
            PolicyId::Nmsr(Some(c)) | PolicyId::MsrSeq(Some(c)) | PolicyId::MsrRand(Some(c)) => {
                write!(f, ":{c}")
            }
            _ => Ok(()),
        }
    }
}

impl FromStr for PolicyId {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<PolicyId> {
        PolicyId::parse(s)
    }
}

/// Instantiate a policy for a workload (CLI / config / sweep entry
/// point). Workload-dependent validation (MSFQ's one-or-all requirement,
/// threshold bounds) happens here, not at parse time.
pub fn build(id: &PolicyId, wl: &Workload) -> anyhow::Result<Box<dyn Policy + Send>> {
    Ok(match *id {
        PolicyId::Fcfs => Box::new(Fcfs::new()),
        PolicyId::FirstFit => Box::new(FirstFit::new()),
        PolicyId::Msf => Box::new(Msf::new()),
        PolicyId::Msfq(ell) => Box::new(Msfq::new(wl, ell.unwrap_or(wl.k.saturating_sub(1)))?),
        PolicyId::StaticQs(ell) => {
            Box::new(StaticQuickswap::new(wl, ell.unwrap_or(wl.k.saturating_sub(1))))
        }
        PolicyId::AdaptiveQs => Box::new(AdaptiveQuickswap::new()),
        PolicyId::Nmsr(cycle) => Box::new(Nmsr::new(wl, cycle.unwrap_or(50.0))?),
        PolicyId::ServerFilling => Box::new(ServerFilling::new()),
        PolicyId::MsrSeq(cycle) => Box::new(MsrSeq::new(wl, cycle.unwrap_or(50.0))?),
        PolicyId::MsrRand(cycle) => Box::new(MsrRand::new(wl, cycle.unwrap_or(50.0))?),
    })
}

/// All nonpreemptive policies used across the paper's figures.
pub const NONPREEMPTIVE: &[PolicyId] = &[
    PolicyId::Fcfs,
    PolicyId::FirstFit,
    PolicyId::Msf,
    PolicyId::Msfq(None),
    PolicyId::StaticQs(None),
    PolicyId::AdaptiveQs,
    PolicyId::Nmsr(None),
    PolicyId::MsrSeq(None),
    PolicyId::MsrRand(None),
];

#[cfg(test)]
mod tests {
    use super::PolicyId;

    #[test]
    fn policy_id_parse_display_roundtrip() {
        for s in [
            "fcfs",
            "first-fit",
            "msf",
            "msfq",
            "msfq:31",
            "static-qs",
            "static-qs:7",
            "adaptive-qs",
            "nmsr",
            "nmsr:50",
            "server-filling",
            "msr-seq",
            "msr-seq:25",
            "msr-rand",
            "msr-rand:12.5",
        ] {
            let id = PolicyId::parse(s).unwrap();
            assert_eq!(id.to_string(), s, "canonical spelling must round-trip");
            assert_eq!(PolicyId::parse(&id.to_string()).unwrap(), id);
        }
        // Aliases parse to the canonical id.
        assert_eq!(PolicyId::parse("ff").unwrap(), PolicyId::FirstFit);
        assert_eq!(PolicyId::parse("sf").unwrap(), PolicyId::ServerFilling);
        assert_eq!(PolicyId::parse("staticqs:3").unwrap(), PolicyId::StaticQs(Some(3)));
        // FromStr mirrors parse.
        assert_eq!("msfq:7".parse::<PolicyId>().unwrap(), PolicyId::Msfq(Some(7)));
    }

    #[test]
    fn policy_id_errors_list_valid_policies() {
        let err = PolicyId::parse("bogus").unwrap_err().to_string();
        assert!(err.contains("unknown policy 'bogus'"), "{err}");
        for name in PolicyId::ALL {
            assert!(err.contains(name), "error must list {name}: {err}");
        }
        assert!(PolicyId::parse("msfq:abc").is_err());
        assert!(PolicyId::parse("fcfs:3").is_err());
    }

    #[test]
    fn policy_id_env_suffix() {
        assert_eq!(PolicyId::Msfq(Some(31)).env_suffix(), "MSFQ");
        assert_eq!(PolicyId::FirstFit.env_suffix(), "FIRST_FIT");
        assert_eq!(PolicyId::MsrSeq(None).env_suffix(), "MSR_SEQ");
    }
}
