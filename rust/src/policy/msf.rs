//! Most Servers First (§4.1): whenever servers free up, admit queued jobs
//! in descending order of server need (FIFO within a class) until no
//! further job fits.
//!
//! Consult cache: MSF admits something iff some queued job fits, so the
//! exact skip condition is `free < min need over queued classes` — the
//! shared [`ConsultWatermark`]: an empty full consult records it
//! exactly, arrivals lower it by the arriving class's need, and our own
//! admissions reset it via [`Policy::on_swap_epoch`].

use crate::policy::{ClassId, ConsultWatermark, Decision, PhaseLabel, Policy, SysView};

#[derive(Default, Debug)]
pub struct Msf {
    /// Class indices sorted by descending need (lazily computed once).
    by_need: Vec<usize>,
    /// Consult cache: skip while free capacity is below the watermark.
    watermark: ConsultWatermark,
}

impl Msf {
    pub fn new() -> Msf {
        Msf::default()
    }

    fn ensure_order(&mut self, needs: &[u32]) {
        if self.by_need.len() != needs.len() {
            let mut idx: Vec<usize> = (0..needs.len()).collect();
            idx.sort_by_key(|&c| std::cmp::Reverse(needs[c]));
            self.by_need = idx;
        }
    }
}

/// Shared MSF admission pass: admit greedily in descending-need order.
/// Returns the number of admissions pushed and the minimum need among
/// classes with a non-empty queue (`u32::MAX` if none) — the exact
/// free-capacity watermark whenever nothing was admitted.
pub(crate) fn msf_admit(sys: &SysView<'_>, by_need: &[usize], out: &mut Decision) -> (usize, u32) {
    let mut free = sys.free();
    let mut count = 0;
    let mut min_need = u32::MAX;
    for &c in by_need {
        let queued = sys.queued[c] as usize;
        if queued == 0 {
            continue;
        }
        let need = sys.needs[c];
        min_need = min_need.min(need);
        if need > free {
            continue;
        }
        let can_take = (free / need) as usize;
        for id in sys.queued_iter(c).take(can_take.min(queued)) {
            out.admit.push(id);
            free -= need;
            count += 1;
        }
    }
    (count, min_need)
}

impl Policy for Msf {
    fn name(&self) -> String {
        "MSF".into()
    }

    fn schedule(&mut self, sys: &SysView<'_>, out: &mut Decision) {
        if self.watermark.blocks(sys.free()) {
            return; // no queued job can fit: provably empty consult
        }
        self.ensure_order(sys.needs);
        let (admitted, min_need) = msf_admit(sys, &self.by_need, out);
        self.watermark.set(if admitted == 0 { min_need } else { 0 });
    }

    fn on_arrival(&mut self, _class: ClassId, need: u32) {
        self.watermark.observe_arrival(need);
    }

    fn on_swap_epoch(&mut self) {
        self.watermark.reset();
    }

    fn set_consult_cache(&mut self, enabled: bool) {
        self.watermark.set_enabled(enabled);
    }

    /// In the one-or-all case MSF behaves like MSFQ with ℓ=0: label
    /// phase 1 while heavies run, phase 2/3 while lights run.
    fn phase_label(&self, sys: &SysView<'_>) -> PhaseLabel {
        one_or_all_label(sys)
    }
}

/// Phase labelling shared by MSF/MSFQ for one-or-all workloads: find the
/// light (need 1) and heavy (need k) classes and classify the instant.
pub(crate) fn one_or_all_label(sys: &SysView<'_>) -> PhaseLabel {
    let mut light = None;
    let mut heavy = None;
    for (c, &n) in sys.needs.iter().enumerate() {
        if n == 1 {
            light = Some(c);
        } else if n == sys.k {
            heavy = Some(c);
        }
    }
    let (l, h) = match (light, heavy) {
        (Some(l), Some(h)) => (l, h),
        _ => return 0,
    };
    if sys.running[h] > 0 {
        1
    } else if sys.running[l] > 0 {
        if sys.in_system(l) >= sys.k {
            2
        } else if sys.queued[l] > 0 {
            4 // draining: lights waiting but not admitted
        } else {
            3
        }
    } else {
        0 // idle
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::test_support::Harness;

    #[test]
    fn prefers_larger_needs() {
        // k=8, classes need {1, 4}. Queue: 6 small then 2 big.
        let mut h = Harness::new(8, &[1, 4]);
        for i in 0..6 {
            h.arrive(0, i as f64 * 0.01);
        }
        let b1 = h.arrive(1, 0.9);
        let b2 = h.arrive(1, 0.95);
        let admitted = h.consult(&mut Msf::new());
        // Both 4-server jobs run; no 1-server job fits afterwards.
        assert!(admitted.contains(&b1) && admitted.contains(&b2));
        assert_eq!(h.used(), 8);
        assert_eq!(h.running[0], 0);
    }

    #[test]
    fn fills_remainder_with_small_jobs() {
        let mut h = Harness::new(8, &[1, 3]);
        h.arrive(1, 0.0); // 3
        h.arrive(1, 0.1); // 3 → 6 used
        for i in 0..5 {
            h.arrive(0, 0.2 + i as f64 * 0.01);
        }
        h.consult(&mut Msf::new());
        assert_eq!(h.used(), 8); // 2 big + 2 small
        assert_eq!(h.running[0], 2);
    }

    #[test]
    fn one_or_all_alternates_exhaustively() {
        // k=4 one-or-all. Heavy arrives first, then lights queue behind.
        let mut h = Harness::new(4, &[1, 4]);
        let hv = h.arrive(1, 0.0);
        let mut p = Msf::new();
        assert_eq!(h.consult(&mut p), vec![hv]);
        for i in 0..3 {
            h.arrive(0, 0.1 + i as f64 * 0.01);
        }
        assert!(h.consult(&mut p).is_empty(), "lights blocked behind heavy");
        h.complete(hv, 1.0);
        let admitted = h.consult(&mut p);
        assert_eq!(admitted.len(), 3, "all lights admitted once heavy done");
    }
}
