//! Most Servers First (§4.1): whenever servers free up, admit queued jobs
//! in descending order of server need (FIFO within a class) until no
//! further job fits.
//!
//! Consult cache: MSF admits something iff some queued job fits, so the
//! exact skip condition is `free < min need over queued classes` — read
//! straight off the driver-maintained [`crate::sim::QueueIndex`] in
//! O(log C). No policy-side watermark state remains: the index is exact
//! at every consult, including across admission batches.

use crate::policy::{Decision, PhaseLabel, Policy, SysView};

#[derive(Default, Debug)]
pub struct Msf {
    /// Incremental consult cache enabled (engine-driven).
    cache: bool,
}

impl Msf {
    pub fn new() -> Msf {
        Msf::default()
    }
}

/// Shared MSF admission pass: admit greedily in descending-need order
/// (ties by ascending class id, FIFO within a class), walking the queue
/// index's need-ranked Fenwick tree — each step finds the next-largest
/// fitting class with a queued job in O(log C), skipping empty classes
/// entirely. Returns the number of admissions pushed.
pub(crate) fn msf_admit(sys: &SysView<'_>, out: &mut Decision) -> usize {
    let idx = sys.queue_index();
    let mut count = 0;
    let mut bound = idx.num_ranks();
    // Ranks decrease strictly, so each class is visited at most once and
    // the engine-maintained queued counts stay valid mid-consult.
    if sys.capacity.is_scalar() {
        let mut free = sys.free();
        while let Some(rank) = idx.max_fitting_rank_below(bound, free) {
            let c = idx.class_at_rank(rank);
            let need = idx.need_at_rank(rank);
            let can_take = ((free / need) as usize).min(sys.queued[c] as usize);
            for id in sys.queued_iter(c).take(can_take) {
                out.admit.push(id);
                free -= need;
                count += 1;
            }
            bound = rank;
        }
    } else {
        // Vector twin: the same descending server-need walk, but each
        // candidate class must fit its whole demand vector and the batch
        // size comes from vector packing.
        let mut free = sys.free_vec();
        while let Some(rank) = idx.max_dominated_rank_below(bound, &free) {
            let c = idx.class_at_rank(rank);
            let demand = idx.demand_of(c);
            let can_take = (demand.max_pack(&free) as usize).min(sys.queued[c] as usize);
            for id in sys.queued_iter(c).take(can_take) {
                out.admit.push(id);
                free.sub_assign(&demand);
                count += 1;
            }
            bound = rank;
        }
    }
    count
}

impl Policy for Msf {
    fn name(&self) -> String {
        "MSF".into()
    }

    fn schedule(&mut self, sys: &SysView<'_>, out: &mut Decision) {
        // Exact: no queued job fits, the consult is empty. At d=1 this
        // is the scalar `free() < min_queued_need()` watermark.
        if self.cache && !sys.queue_index().queued_demand_fits(&sys.free_vec()) {
            return;
        }
        msf_admit(sys, out);
    }

    fn set_consult_cache(&mut self, enabled: bool) {
        self.cache = enabled;
    }

    /// In the one-or-all case MSF behaves like MSFQ with ℓ=0: label
    /// phase 1 while heavies run, phase 2/3 while lights run.
    fn phase_label(&self, sys: &SysView<'_>) -> PhaseLabel {
        one_or_all_label(sys)
    }
}

/// Phase labelling shared by MSF/MSFQ for one-or-all workloads: find the
/// light (need 1) and heavy (need k) classes and classify the instant.
pub(crate) fn one_or_all_label(sys: &SysView<'_>) -> PhaseLabel {
    let mut light = None;
    let mut heavy = None;
    for (c, &n) in sys.needs.iter().enumerate() {
        if n == 1 {
            light = Some(c);
        } else if n == sys.k {
            heavy = Some(c);
        }
    }
    let (l, h) = match (light, heavy) {
        (Some(l), Some(h)) => (l, h),
        _ => return 0,
    };
    if sys.running[h] > 0 {
        1
    } else if sys.running[l] > 0 {
        if sys.in_system(l) >= sys.k {
            2
        } else if sys.queued[l] > 0 {
            4 // draining: lights waiting but not admitted
        } else {
            3
        }
    } else {
        0 // idle
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::test_support::Harness;

    #[test]
    fn prefers_larger_needs() {
        // k=8, classes need {1, 4}. Queue: 6 small then 2 big.
        let mut h = Harness::new(8, &[1, 4]);
        for i in 0..6 {
            h.arrive(0, i as f64 * 0.01);
        }
        let b1 = h.arrive(1, 0.9);
        let b2 = h.arrive(1, 0.95);
        let admitted = h.consult(&mut Msf::new());
        // Both 4-server jobs run; no 1-server job fits afterwards.
        assert!(admitted.contains(&b1) && admitted.contains(&b2));
        assert_eq!(h.used(), 8);
        assert_eq!(h.running[0], 0);
    }

    #[test]
    fn fills_remainder_with_small_jobs() {
        let mut h = Harness::new(8, &[1, 3]);
        h.arrive(1, 0.0); // 3
        h.arrive(1, 0.1); // 3 → 6 used
        for i in 0..5 {
            h.arrive(0, 0.2 + i as f64 * 0.01);
        }
        h.consult(&mut Msf::new());
        assert_eq!(h.used(), 8); // 2 big + 2 small
        assert_eq!(h.running[0], 2);
    }

    #[test]
    fn one_or_all_alternates_exhaustively() {
        // k=4 one-or-all. Heavy arrives first, then lights queue behind.
        let mut h = Harness::new(4, &[1, 4]);
        let hv = h.arrive(1, 0.0);
        let mut p = Msf::new();
        assert_eq!(h.consult(&mut p), vec![hv]);
        for i in 0..3 {
            h.arrive(0, 0.1 + i as f64 * 0.01);
        }
        assert!(h.consult(&mut p).is_empty(), "lights blocked behind heavy");
        h.complete(hv, 1.0);
        let admitted = h.consult(&mut p);
        assert_eq!(admitted.len(), 3, "all lights admitted once heavy done");
    }
}
