//! ServerFilling [22] — the preemptive comparison policy of Appendix D.
//!
//! At every event, take the minimal prefix of the arrival-ordered queue
//! whose total server need is ≥ k (or all jobs if the total is smaller),
//! then serve jobs from that prefix largest-need-first while they fit.
//! With power-of-two needs dividing k this fills all k servers whenever
//! ≥ k servers' worth of work is present. Preemption is assumed free
//! (preempt-resume; remaining service is tracked exactly).
//!
//! Consult cache: the target service set is a pure function of prefix
//! *membership* (plus needs), which the [`crate::sim::job::JobTable`]
//! now maintains incrementally — the minimal arrival-order prefix with
//! total need ≥ k, updated O(1) amortized per insert/remove, with a
//! version counter bumped exactly when membership changes. A consult
//! whose prefix version matches the last full recompute is provably a
//! no-op (the running set already equals the greedy fill of an
//! unchanged prefix): arrivals landing *beyond* the prefix — the common
//! case in a long queue — no longer trigger a recompute at all, the
//! former O(prefix) cumulative-sum walk is bounded by the precomputed
//! prefix length, and the former O(n) suffix sweep for stray running
//! jobs is skipped whenever the prefix accounts for every running job
//! (always, in driver operation: the prefix end is monotone in arrival
//! order, so running jobs never fall out of it).

use crate::policy::{ClassId, Decision, JobId, PhaseLabel, Policy, SysView};

#[derive(Debug)]
pub struct ServerFilling {
    /// Scratch: candidate prefix (id, class, running, selected).
    prefix: Vec<(JobId, ClassId, bool, bool)>,
    /// Incremental consult cache enabled (engine-driven).
    cache: bool,
    /// Prefix version at the last full recompute (`u64::MAX` = none).
    last_version: u64,
}

impl Default for ServerFilling {
    fn default() -> Self {
        ServerFilling {
            prefix: Vec::new(),
            cache: false,
            last_version: u64::MAX,
        }
    }
}

impl ServerFilling {
    pub fn new() -> ServerFilling {
        ServerFilling::default()
    }
}

impl Policy for ServerFilling {
    fn name(&self) -> String {
        "ServerFilling".into()
    }

    fn is_preemptive(&self) -> bool {
        true
    }

    fn schedule(&mut self, sys: &SysView<'_>, out: &mut Decision) {
        let version = sys.jobs.prefix_version();
        if self.cache && version == self.last_version {
            return; // prefix membership unchanged: the set is settled
        }
        self.last_version = version;
        // 1. Collect the incrementally-maintained minimal prefix with
        //    total need ≥ k (or everything, when the total is smaller).
        self.prefix.clear();
        let mut left = sys.jobs.prefix_len() as usize;
        let mut running_in_prefix = 0u32;
        let prefix = &mut self.prefix;
        sys.for_each_in_arrival_order(&mut |id, class, running| {
            if left == 0 {
                return false;
            }
            left -= 1;
            prefix.push((id, class, running, false));
            running_in_prefix += u32::from(running);
            left > 0
        });
        debug_assert_eq!(self.prefix.len() as u32, sys.jobs.prefix_len());

        // 2. Largest-need-first greedy fill within the prefix
        //    (stable: arrival order breaks ties). Under the vector model
        //    the order key stays the server need; the fit check is the
        //    whole demand vector.
        self.prefix
            .sort_by_key(|&(_, class, _, _)| std::cmp::Reverse(sys.needs[class]));
        if sys.capacity.is_scalar() {
            let mut free = sys.k;
            for e in self.prefix.iter_mut() {
                let need = sys.needs[e.1];
                if need <= free {
                    e.3 = true;
                    free -= need;
                }
            }
        } else {
            let mut free = sys.capacity;
            for e in self.prefix.iter_mut() {
                let demand = sys.demands[e.1];
                if demand.fits_in(&free) {
                    e.3 = true;
                    free.sub_assign(&demand);
                }
            }
        }

        // 3. Diff against the current service set.
        for &(id, _, running, sel) in self.prefix.iter() {
            if running && !sel {
                out.preempt.push(id);
            } else if !running && sel {
                out.admit.push(id);
            }
        }
        // Jobs beyond the prefix that are running must be preempted too.
        // The prefix end only moves forward in arrival order, so under
        // driver operation every running job sits inside it and this
        // sweep never runs; the index's O(1) running total proves it.
        if running_in_prefix != sys.queue_index().running_total() {
            let in_prefix_len = self.prefix.len();
            let preempt = &mut out.preempt;
            let mut idx = 0usize;
            sys.for_each_in_arrival_order(&mut |id, _class, running| {
                idx += 1;
                if idx > in_prefix_len && running {
                    preempt.push(id);
                }
                true
            });
        }
    }

    // on_arrival / on_departure: intentionally the default no-ops — the
    // JobTable's prefix version carries exactly the invalidation signal
    // (arrivals beyond the prefix and departures of non-members change
    // nothing and bump nothing).

    // on_swap_epoch: intentionally the default no-op — applying our own
    // decision makes the running set equal the greedy fill exactly, and
    // admissions/preemptions never change prefix membership, so the
    // fixed-point re-consult sees an unchanged version and skips.

    fn set_consult_cache(&mut self, enabled: bool) {
        self.cache = enabled;
        self.last_version = u64::MAX;
    }

    fn phase_label(&self, _sys: &SysView<'_>) -> PhaseLabel {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::test_support::Harness;

    /// With ≥ k total demand and power-of-two needs, all k servers busy.
    #[test]
    fn fills_all_servers() {
        let mut h = Harness::new(8, &[1, 2, 4, 8]);
        let mut p = ServerFilling::new();
        h.arrive(1, 0.0); // 2
        h.arrive(0, 0.1); // 1
        h.arrive(2, 0.2); // 4
        h.arrive(0, 0.3); // 1
        h.arrive(2, 0.4); // 4 — prefix reaches ≥ 8 at job 3 already
        h.consult(&mut p);
        assert_eq!(h.used(), 8, "ServerFilling must fill k when load ≥ k");
    }

    /// A newly arrived large job displaces smaller later arrivals via
    /// preemption when the prefix shifts.
    #[test]
    fn preempts_when_prefix_changes() {
        let mut h = Harness::new(4, &[1, 4]);
        let mut p = ServerFilling::new();
        let l1 = h.arrive(0, 0.0);
        let l2 = h.arrive(0, 0.1);
        h.consult(&mut p);
        assert_eq!(h.used(), 2);
        // Heavy arrives: prefix = {l1, l2, heavy} (total 6 ≥ 4), sorted
        // by need → heavy first, fills k=4 alone → lights preempted.
        let hv = h.arrive(1, 0.5);
        let adm = h.consult(&mut p);
        assert!(adm.contains(&hv));
        assert_eq!(h.used(), 4);
        assert_eq!(h.running[0], 0);
        assert!(h.jobs.is_queued(l1) && h.jobs.is_queued(l2));
        // Heavy completes → lights resume.
        h.complete(hv, 1.5);
        h.consult(&mut p);
        assert_eq!(h.running[0], 2);
    }

    /// Below k total demand everything runs.
    #[test]
    fn runs_everything_under_capacity() {
        let mut h = Harness::new(8, &[1, 2]);
        let mut p = ServerFilling::new();
        h.arrive(0, 0.0);
        h.arrive(1, 0.1);
        h.arrive(1, 0.2);
        h.consult(&mut p);
        assert_eq!(h.used(), 5);
    }
}
