//! ServerFilling [22] — the preemptive comparison policy of Appendix D.
//!
//! At every event, take the minimal prefix of the arrival-ordered queue
//! whose total server need is ≥ k (or all jobs if the total is smaller),
//! then serve jobs from that prefix largest-need-first while they fit.
//! With power-of-two needs dividing k this fills all k servers whenever
//! ≥ k servers' worth of work is present. Preemption is assumed free
//! (preempt-resume; remaining service is tracked exactly).
//!
//! Consult cache: the target service set is a pure function of the
//! arrival order, which admissions and preemptions do not touch — so
//! applying this policy's own decision always reaches a fixed point,
//! and the post-decision re-consult is skippable. A dirty flag set by
//! `on_arrival`/`on_departure` (the only transitions that change the
//! prefix) gates the full recompute; `on_swap_epoch` deliberately keeps
//! the cache warm.

use crate::policy::{ClassId, Decision, JobId, PhaseLabel, Policy, SysView};

#[derive(Debug)]
pub struct ServerFilling {
    /// Scratch: candidate prefix (id, need, running).
    prefix: Vec<(JobId, u32, bool)>,
    /// Scratch: selected job ids.
    selected: Vec<JobId>,
    /// Incremental consult cache enabled (engine-driven).
    cache: bool,
    /// The arrival order changed since the last full consult.
    dirty: bool,
}

impl Default for ServerFilling {
    fn default() -> Self {
        ServerFilling {
            prefix: Vec::new(),
            selected: Vec::new(),
            cache: false,
            dirty: true,
        }
    }
}

impl ServerFilling {
    pub fn new() -> ServerFilling {
        ServerFilling::default()
    }
}

impl Policy for ServerFilling {
    fn name(&self) -> String {
        "ServerFilling".into()
    }

    fn is_preemptive(&self) -> bool {
        true
    }

    fn schedule(&mut self, sys: &SysView<'_>, out: &mut Decision) {
        if self.cache && !self.dirty {
            return; // arrival order unchanged: the service set is settled
        }
        self.dirty = false;
        // 1. Minimal prefix with total need ≥ k (or everything).
        self.prefix.clear();
        let mut total = 0u32;
        let k = sys.k;
        let prefix = &mut self.prefix;
        sys.for_each_in_arrival_order(&mut |id, class, running| {
            prefix.push((id, sys.needs[class], running));
            total += sys.needs[class];
            total < k
        });

        // 2. Largest-need-first greedy fill within the prefix
        //    (stable: arrival order breaks ties).
        self.prefix.sort_by_key(|&(_, need, _)| std::cmp::Reverse(need));
        self.selected.clear();
        let mut free = k;
        for &(id, need, _) in self.prefix.iter() {
            if need <= free {
                self.selected.push(id);
                free -= need;
            }
        }

        // 3. Diff against the current service set.
        for &(id, _, running) in self.prefix.iter() {
            let want = self.selected.contains(&id);
            if running && !want {
                out.preempt.push(id);
            } else if !running && want {
                out.admit.push(id);
            }
        }
        // Jobs beyond the prefix that are running must be preempted too
        // (they can only be running due to an earlier, different prefix).
        let in_prefix_len = self.prefix.len();
        let prefix_ref = &self.prefix;
        let preempt = &mut out.preempt;
        let mut idx = 0usize;
        sys.for_each_in_arrival_order(&mut |id, _class, running| {
            idx += 1;
            if idx <= in_prefix_len {
                return true;
            }
            if running && !prefix_ref.iter().any(|&(p, _, _)| p == id) {
                preempt.push(id);
            }
            true
        });
    }

    fn on_arrival(&mut self, _class: ClassId, _need: u32) {
        self.dirty = true;
    }

    fn on_departure(&mut self, _class: ClassId, _need: u32) {
        self.dirty = true;
    }

    // on_swap_epoch: intentionally the default no-op — applying our own
    // decision makes the running set equal `selected` exactly, and the
    // prefix only depends on the (unchanged) arrival order, so the
    // fixed-point re-consult would be empty.

    fn set_consult_cache(&mut self, enabled: bool) {
        self.cache = enabled;
        self.dirty = true;
    }

    fn phase_label(&self, _sys: &SysView<'_>) -> PhaseLabel {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::test_support::Harness;

    /// With ≥ k total demand and power-of-two needs, all k servers busy.
    #[test]
    fn fills_all_servers() {
        let mut h = Harness::new(8, &[1, 2, 4, 8]);
        let mut p = ServerFilling::new();
        h.arrive(1, 0.0); // 2
        h.arrive(0, 0.1); // 1
        h.arrive(2, 0.2); // 4
        h.arrive(0, 0.3); // 1
        h.arrive(2, 0.4); // 4 — prefix reaches ≥ 8 at job 3 already
        h.consult(&mut p);
        assert_eq!(h.used(), 8, "ServerFilling must fill k when load ≥ k");
    }

    /// A newly arrived large job displaces smaller later arrivals via
    /// preemption when the prefix shifts.
    #[test]
    fn preempts_when_prefix_changes() {
        let mut h = Harness::new(4, &[1, 4]);
        let mut p = ServerFilling::new();
        let l1 = h.arrive(0, 0.0);
        let l2 = h.arrive(0, 0.1);
        h.consult(&mut p);
        assert_eq!(h.used(), 2);
        // Heavy arrives: prefix = {l1, l2, heavy} (total 6 ≥ 4), sorted
        // by need → heavy first, fills k=4 alone → lights preempted.
        let hv = h.arrive(1, 0.5);
        let adm = h.consult(&mut p);
        assert!(adm.contains(&hv));
        assert_eq!(h.used(), 4);
        assert_eq!(h.running[0], 0);
        assert!(h.jobs.is_queued(l1) && h.jobs.is_queued(l2));
        // Heavy completes → lights resume.
        h.complete(hv, 1.5);
        h.consult(&mut p);
        assert_eq!(h.running[0], 2);
    }

    /// Below k total demand everything runs.
    #[test]
    fn runs_everything_under_capacity() {
        let mut h = Harness::new(8, &[1, 2]);
        let mut p = ServerFilling::new();
        h.arrive(0, 0.0);
        h.arrive(1, 0.1);
        h.arrive(1, 0.2);
        h.consult(&mut p);
        assert_eq!(h.used(), 5);
    }
}
