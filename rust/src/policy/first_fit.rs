//! First-Fit: like FCFS, but keeps scanning the queue in arrival order
//! past jobs that do not fit, admitting any later job that does
//! (eliminates head-of-the-line blocking at the cost of potentially
//! starving large jobs).

use crate::policy::{Decision, Policy, SysView};

#[derive(Default, Debug)]
pub struct FirstFit;

impl FirstFit {
    pub fn new() -> FirstFit {
        FirstFit
    }
}

impl Policy for FirstFit {
    fn name(&self) -> String {
        "First-Fit".into()
    }

    fn schedule(&mut self, sys: &SysView<'_>, out: &mut Decision) {
        let mut free = sys.free();
        if free == 0 {
            return;
        }
        // The smallest need among queued classes lets us stop the scan
        // early once nothing can possibly fit.
        let min_need = sys
            .queued
            .iter()
            .enumerate()
            .filter(|(_, &q)| q > 0)
            .map(|(c, _)| sys.needs[c])
            .min()
            .unwrap_or(u32::MAX);
        if min_need > free {
            return;
        }
        sys.for_each_in_arrival_order(&mut |id, class, running| {
            if running {
                return true;
            }
            let need = sys.needs[class];
            if need <= free {
                out.admit.push(id);
                free -= need;
            }
            free >= min_need // keep scanning while anything could fit
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::test_support::Harness;

    #[test]
    fn skips_blocked_head() {
        let mut h = Harness::new(4, &[1, 4]);
        h.arrive(0, 0.0);
        h.arrive(1, 0.1); // need 4: cannot fit after first admit
        let third = h.arrive(0, 0.2);
        let admitted = h.consult(&mut FirstFit::new());
        assert!(admitted.contains(&third), "first-fit must backfill");
        assert_eq!(h.used(), 2);
    }

    #[test]
    fn respects_arrival_order_within_fits() {
        let mut h = Harness::new(3, &[2, 1]);
        let a = h.arrive(0, 0.0); // need 2
        let b = h.arrive(0, 0.1); // need 2: doesn't fit after a
        let c = h.arrive(1, 0.2); // need 1: fits
        let admitted = h.consult(&mut FirstFit::new());
        assert_eq!(admitted, vec![a, c]);
        assert!(h.jobs.is_queued(b));
    }
}
