//! First-Fit: like FCFS, but keeps scanning the queue in arrival order
//! past jobs that do not fit, admitting any later job that does
//! (eliminates head-of-the-line blocking at the cost of potentially
//! starving large jobs).
//!
//! Consult cache: First-Fit admits something iff some queued job fits,
//! so `free < min need over queued classes` is the exact empty-consult
//! condition — read in O(log C) from the driver-maintained
//! [`crate::sim::QueueIndex`]. The predicate is exact and cheap enough
//! to evaluate on every consult, so First-Fit carries no cache state at
//! all (`set_consult_cache` is the default no-op): cached and uncached
//! consults are the same code path by construction.
//!
//! Scan bounds: the walk starts at the HoL cursor (every earlier job is
//! in service) and visits only queued jobs, and the index's
//! **need-weighted Fenwick prefix**
//! ([`queued_need_fitting`](crate::sim::QueueIndex::queued_need_fitting))
//! caps it — once the scan has seen that much fitting mass, every
//! unvisited queued job needs more than the initial free capacity and
//! can never be admitted this consult, so the scan stops instead of
//! walking the (possibly enormous, at ρ → 1) tail of too-large jobs.
//! Neither bound changes any admission decision: they cut exactly the
//! suffix of provable non-admissions.

use crate::policy::{Decision, Policy, SysView};

#[derive(Default, Debug)]
pub struct FirstFit;

impl FirstFit {
    pub fn new() -> FirstFit {
        FirstFit
    }
}

impl Policy for FirstFit {
    fn name(&self) -> String {
        "First-Fit".into()
    }

    fn schedule(&mut self, sys: &SysView<'_>, out: &mut Decision) {
        let admit = &mut out.admit;
        let idx = sys.queue_index();
        if sys.capacity.is_scalar() {
            let free0 = sys.free();
            // Need-weighted fitting mass: zero iff no queued job fits (the
            // exact skip), and otherwise the scan's work bound.
            let mut unseen_fit = idx.queued_need_fitting(free0);
            if unseen_fit == 0 {
                return;
            }
            let min_need = idx.min_queued_need();
            let mut free = free0;
            sys.for_each_queued_in_arrival_order(&mut |id, class| {
                let need = sys.needs[class];
                if need <= free0 {
                    // Part of the fitting mass whether or not it still fits
                    // after earlier admissions shrank `free`.
                    if need <= free {
                        admit.push(id);
                        free -= need;
                    }
                    unseen_fit -= need as u64;
                }
                // Stop when all fitting mass is seen or nothing else could
                // possibly fit in what's left.
                unseen_fit > 0 && free >= min_need
            });
        } else {
            // Vector twin: fitting mass (server-weighted, over jobs whose
            // whole demand vector fits the initial free vector) is the
            // exact skip and the scan bound; the per-job test is the
            // component-wise fit.
            let free0 = sys.free_vec();
            let mut unseen_fit = idx.queued_mass_fitting(&free0);
            if unseen_fit == 0 {
                return;
            }
            let mut free = free0;
            sys.for_each_queued_in_arrival_order(&mut |id, class| {
                let demand = sys.demands[class];
                if demand.fits_in(&free0) {
                    if demand.fits_in(&free) {
                        admit.push(id);
                        free.sub_assign(&demand);
                    }
                    unseen_fit -= demand.servers() as u64;
                }
                unseen_fit > 0
            });
        }
        debug_assert!(!admit.is_empty(), "fitting-mass predicate admitted nothing");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::test_support::Harness;

    #[test]
    fn skips_blocked_head() {
        let mut h = Harness::new(4, &[1, 4]);
        h.arrive(0, 0.0);
        h.arrive(1, 0.1); // need 4: cannot fit after first admit
        let third = h.arrive(0, 0.2);
        let admitted = h.consult(&mut FirstFit::new());
        assert!(admitted.contains(&third), "first-fit must backfill");
        assert_eq!(h.used(), 2);
    }

    #[test]
    fn respects_arrival_order_within_fits() {
        let mut h = Harness::new(3, &[2, 1]);
        let a = h.arrive(0, 0.0); // need 2
        let b = h.arrive(0, 0.1); // need 2: doesn't fit after a
        let c = h.arrive(1, 0.2); // need 1: fits
        let admitted = h.consult(&mut FirstFit::new());
        assert_eq!(admitted, vec![a, c]);
        assert!(h.jobs.is_queued(b));
    }

    /// The weighted-mass bound stops the scan without changing any
    /// decision: with a long tail of too-large jobs behind the fitting
    /// ones, admissions match the unbounded arrival-order semantics.
    #[test]
    fn fitting_mass_bound_preserves_decisions() {
        let mut h = Harness::new(8, &[1, 2, 8]);
        // Fitting heads...
        let a = h.arrive(0, 0.0); // need 1
        let b = h.arrive(1, 0.1); // need 2
        // ...then a deep tail of need-8 jobs that can never fit at
        // free0 = 8 - 0 ... they fit individually when the system is
        // empty, so block some capacity first:
        let big = h.arrive(2, 0.2);
        let admitted = h.consult(&mut FirstFit::new());
        assert_eq!(admitted, vec![a, b, /* big does not fit */]);
        for i in 0..50 {
            h.arrive(2, 1.0 + i as f64 * 0.01); // tail of need-8 jobs
        }
        let c = h.arrive(0, 2.0); // a late fitting job behind the tail
        let admitted = h.consult(&mut FirstFit::new());
        assert_eq!(admitted, vec![c], "must backfill past the need-8 tail");
        assert!(h.jobs.is_queued(big));
        assert_eq!(h.used(), 4);
    }
}
