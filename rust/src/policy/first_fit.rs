//! First-Fit: like FCFS, but keeps scanning the queue in arrival order
//! past jobs that do not fit, admitting any later job that does
//! (eliminates head-of-the-line blocking at the cost of potentially
//! starving large jobs).
//!
//! Consult cache: First-Fit admits something iff some queued job fits,
//! so `free < min need over queued classes` is the exact empty-consult
//! condition (the same [`ConsultWatermark`] as MSF, maintained the same
//! way).

use crate::policy::{ClassId, ConsultWatermark, Decision, Policy, SysView};

#[derive(Default, Debug)]
pub struct FirstFit {
    /// Consult cache: skip while free capacity is below the watermark.
    watermark: ConsultWatermark,
}

impl FirstFit {
    pub fn new() -> FirstFit {
        FirstFit::default()
    }
}

impl Policy for FirstFit {
    fn name(&self) -> String {
        "First-Fit".into()
    }

    fn schedule(&mut self, sys: &SysView<'_>, out: &mut Decision) {
        let free0 = sys.free();
        if self.watermark.blocks(free0) {
            return; // no queued job can fit: provably empty consult
        }
        // The smallest need among queued classes lets us stop the scan
        // early once nothing can possibly fit.
        let min_need = sys
            .queued
            .iter()
            .enumerate()
            .filter(|(_, &q)| q > 0)
            .map(|(c, _)| sys.needs[c])
            .min()
            .unwrap_or(u32::MAX);
        if min_need > free0 {
            // Exact: nothing fits right now (MAX when the queue is empty).
            self.watermark.set(min_need);
            return;
        }
        // Something fits, so this scan always admits; our admissions
        // invalidate the watermark (on_swap_epoch resets it and the
        // fixed-point re-consult records the fresh exact value).
        let mut free = free0;
        let admit = &mut out.admit;
        sys.for_each_in_arrival_order(&mut |id, class, running| {
            if running {
                return true;
            }
            let need = sys.needs[class];
            if need <= free {
                admit.push(id);
                free -= need;
            }
            free >= min_need // keep scanning while anything could fit
        });
    }

    fn on_arrival(&mut self, _class: ClassId, need: u32) {
        self.watermark.observe_arrival(need);
    }

    fn on_swap_epoch(&mut self) {
        self.watermark.reset();
    }

    fn set_consult_cache(&mut self, enabled: bool) {
        self.watermark.set_enabled(enabled);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::test_support::Harness;

    #[test]
    fn skips_blocked_head() {
        let mut h = Harness::new(4, &[1, 4]);
        h.arrive(0, 0.0);
        h.arrive(1, 0.1); // need 4: cannot fit after first admit
        let third = h.arrive(0, 0.2);
        let admitted = h.consult(&mut FirstFit::new());
        assert!(admitted.contains(&third), "first-fit must backfill");
        assert_eq!(h.used(), 2);
    }

    #[test]
    fn respects_arrival_order_within_fits() {
        let mut h = Harness::new(3, &[2, 1]);
        let a = h.arrive(0, 0.0); // need 2
        let b = h.arrive(0, 0.1); // need 2: doesn't fit after a
        let c = h.arrive(1, 0.2); // need 1: fits
        let admitted = h.consult(&mut FirstFit::new());
        assert_eq!(admitted, vec![a, c]);
        assert!(h.jobs.is_queued(b));
    }
}
