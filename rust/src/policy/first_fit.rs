//! First-Fit: like FCFS, but keeps scanning the queue in arrival order
//! past jobs that do not fit, admitting any later job that does
//! (eliminates head-of-the-line blocking at the cost of potentially
//! starving large jobs).
//!
//! Consult cache: First-Fit admits something iff some queued job fits,
//! so `free < min need over queued classes` is the exact empty-consult
//! condition — read in O(log C) from the driver-maintained
//! [`crate::sim::QueueIndex`]. The predicate is exact and cheap enough
//! to evaluate on every consult, so First-Fit carries no cache state at
//! all (`set_consult_cache` is the default no-op): cached and uncached
//! consults are the same code path by construction.

use crate::policy::{Decision, Policy, SysView};

#[derive(Default, Debug)]
pub struct FirstFit;

impl FirstFit {
    pub fn new() -> FirstFit {
        FirstFit
    }
}

impl Policy for FirstFit {
    fn name(&self) -> String {
        "First-Fit".into()
    }

    fn schedule(&mut self, sys: &SysView<'_>, out: &mut Decision) {
        let free0 = sys.free();
        // Exact index fit check: the smallest need among queued classes
        // (formerly an O(C) scan per consult).
        let min_need = sys.min_queued_need();
        if min_need > free0 {
            return; // exact: nothing fits (MAX when the queue is empty)
        }
        // Something fits, so this scan always admits.
        let mut free = free0;
        let admit = &mut out.admit;
        sys.for_each_in_arrival_order(&mut |id, class, running| {
            if running {
                return true;
            }
            let need = sys.needs[class];
            if need <= free {
                admit.push(id);
                free -= need;
            }
            free >= min_need // keep scanning while anything could fit
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::test_support::Harness;

    #[test]
    fn skips_blocked_head() {
        let mut h = Harness::new(4, &[1, 4]);
        h.arrive(0, 0.0);
        h.arrive(1, 0.1); // need 4: cannot fit after first admit
        let third = h.arrive(0, 0.2);
        let admitted = h.consult(&mut FirstFit::new());
        assert!(admitted.contains(&third), "first-fit must backfill");
        assert_eq!(h.used(), 2);
    }

    #[test]
    fn respects_arrival_order_within_fits() {
        let mut h = Harness::new(3, &[2, 1]);
        let a = h.arrive(0, 0.0); // need 2
        let b = h.arrive(0, 0.1); // need 2: doesn't fit after a
        let c = h.arrive(1, 0.2); // need 1: fits
        let admitted = h.consult(&mut FirstFit::new());
        assert_eq!(admitted, vec![a, c]);
        assert!(h.jobs.is_queued(b));
    }
}
