//! Random-walk Markovian Service Rate (MSR-Rand), after the MSR
//! framework of [13] (Chen, Grosof & Berg): the same precomputed
//! saturated configurations as [`crate::policy::MsrSeq`] (one per class,
//! ⌊capacity/demand⌋ slots under the vector model), but the modulating
//! chain is a genuine CTMC random walk — exponential holding times with
//! a common mean, and the jump chain picking the next configuration
//! **uniformly at random** among the other classes, independent of queue
//! lengths. Switches are nonpreemptive: admissions stop, the outgoing
//! configuration drains, then the sampled successor activates.
//!
//! The chain runs on a dedicated fixed-seed policy-internal RNG, so a
//! given policy instance's configuration trajectory is deterministic
//! across runs and independent of the workload's arrival/size streams.

use crate::policy::{ClassId, Decision, PhaseLabel, Policy, SysView};
use crate::util::rng::Rng;
use crate::workload::Workload;

#[derive(Debug)]
pub struct MsrRand {
    /// Number of configurations (= classes).
    m: usize,
    /// Mean exponential holding time per configuration.
    hold_mean: f64,
    cur: ClassId,
    switching: bool,
    timer_armed: bool,
    rng: Rng,
    /// Incremental consult cache enabled (engine-driven).
    cache: bool,
}

impl MsrRand {
    /// `cycle` = nominal full-tour duration: the mean holding time is
    /// `cycle / num_classes`, matching MSR-Seq's total dwell per tour in
    /// expectation.
    pub fn new(wl: &Workload, cycle: f64) -> anyhow::Result<MsrRand> {
        anyhow::ensure!(cycle > 0.0, "cycle must be positive");
        let m = wl.num_classes();
        anyhow::ensure!(
            wl.classes.iter().any(|c| c.rate > 0.0),
            "workload has no load"
        );
        Ok(MsrRand {
            m,
            hold_mean: cycle / m as f64,
            cur: 0,
            switching: false,
            timer_armed: false,
            rng: Rng::new(0x6d737272), // deterministic: policy-internal chain
            cache: false,
        })
    }

    fn admit_current(&self, sys: &SysView<'_>, out: &mut Decision) {
        let c = self.cur;
        let slots = sys.demands[c].max_pack(&sys.capacity);
        let can = (slots.saturating_sub(sys.running[c])).min(sys.queued[c]) as usize;
        // Capacity check: other classes may still be draining.
        if sys.capacity.is_scalar() {
            let need = sys.needs[c];
            let mut free = sys.free();
            for id in sys.queued_iter(c).take(can) {
                if need > free {
                    break;
                }
                out.admit.push(id);
                free -= need;
            }
        } else {
            let demand = sys.demands[c];
            let mut free = sys.free_vec();
            for id in sys.queued_iter(c).take(can) {
                if !demand.fits_in(&free) {
                    break;
                }
                out.admit.push(id);
                free.sub_assign(&demand);
            }
        }
    }

    /// Jump chain: uniform over the other configurations (self-loops
    /// excluded so every switch actually changes the service set; with a
    /// single class the walk stays put).
    fn next_config(&mut self) -> ClassId {
        if self.m <= 1 {
            return self.cur;
        }
        let step = 1 + self.rng.index(self.m - 1);
        (self.cur + step) % self.m
    }
}

impl Policy for MsrRand {
    fn name(&self) -> String {
        "MSR-Rand".into()
    }

    fn schedule(&mut self, sys: &SysView<'_>, out: &mut Decision) {
        // Consult-cache fast path. Once the modulating chain is armed, a
        // consult is a no-op (no admissions, no RNG draws, no state
        // change) exactly when mid-switch with the outgoing configuration
        // still draining, or when the active configuration cannot start a
        // job. Unarmed and advance-the-chain consults fall through — they
        // draw from the policy RNG, so skipping them would desynchronize
        // cached and uncached trajectories.
        if self.cache && self.timer_armed {
            if self.switching {
                if sys.used > 0 {
                    return;
                }
            } else {
                let idx = sys.queue_index();
                let c = self.cur;
                let slots = sys.demands[c].max_pack(&sys.capacity);
                let can = slots.saturating_sub(idx.running_of(c)).min(idx.queued_of(c));
                if can == 0 || !idx.can_admit_vec(c, &sys.free_vec()) {
                    return;
                }
            }
        }
        if !self.timer_armed {
            // First consult: arm the modulating chain.
            self.timer_armed = true;
            let hold = self.rng.exp(1.0 / self.hold_mean);
            out.set_timer = Some(sys.now + hold);
        }
        if self.switching {
            // Wait for the previous configuration to drain completely.
            if sys.used > 0 {
                return;
            }
            self.switching = false;
            self.cur = self.next_config();
            let hold = self.rng.exp(1.0 / self.hold_mean);
            out.set_timer = Some(sys.now + hold);
        }
        self.admit_current(sys, out);
    }

    fn on_timer(&mut self, _now: f64) {
        self.switching = true;
    }

    fn set_consult_cache(&mut self, enabled: bool) {
        self.cache = enabled;
    }

    fn phase_label(&self, _sys: &SysView<'_>) -> PhaseLabel {
        if self.switching {
            4
        } else {
            2
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Dist;
    use crate::policy::test_support::Harness;
    use crate::workload::{ClassSpec, Workload};

    fn wl() -> Workload {
        Workload::new(
            4,
            vec![
                ClassSpec::new(1, 1.0, Dist::exp_mean(1.0)),
                ClassSpec::new(4, 0.2, Dist::exp_mean(1.0)),
            ],
        )
    }

    #[test]
    fn serves_only_active_configuration() {
        let w = wl();
        let mut p = MsrRand::new(&w, 10.0).unwrap();
        let mut h = Harness::new(4, &[1, 4]);
        h.arrive(0, 0.0);
        h.arrive(1, 0.1);
        let adm = h.consult(&mut p);
        assert_eq!(adm.len(), 1);
        assert_eq!(h.running[0], 1);
        assert_eq!(h.running[1], 0, "inactive configuration gets nothing");
    }

    #[test]
    fn switch_drains_then_jumps_elsewhere() {
        let w = wl();
        let mut p = MsrRand::new(&w, 10.0).unwrap();
        let mut h = Harness::new(4, &[1, 4]);
        let l = h.arrive(0, 0.0);
        let hv = h.arrive(1, 0.1);
        h.consult(&mut p);
        p.on_timer(1.0);
        h.arrive(0, 1.1);
        assert!(h.consult(&mut p).is_empty(), "no admissions while draining");
        h.complete(l, 2.0);
        // With two classes the self-loop-free walk must land on class 1.
        let adm = h.consult(&mut p);
        assert_eq!(adm, vec![hv]);
        assert_eq!(p.cur, 1);
    }

    #[test]
    fn chain_is_deterministic_per_instance() {
        let w = wl();
        let mk = || MsrRand::new(&w, 10.0).unwrap();
        let (mut a, mut b) = (mk(), mk());
        let mut sequence = |p: &mut MsrRand| -> Vec<ClassId> {
            (0..16).map(|_| p.next_config()).collect()
        };
        assert_eq!(sequence(&mut a), sequence(&mut b));
    }
}
