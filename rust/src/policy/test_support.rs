//! A miniature system-state container: manually arrive/complete jobs and
//! collect policy decisions, without the full DES engine. Used by unit
//! tests, the property-test suite, AND the coordinator daemon (which
//! drives it from real-time events instead of simulated ones).

use crate::policy::{Decision, JobId, Policy, SysView};
use crate::sim::job::{ClassFifos, JobState, JobTable, QueueIndex};
use crate::workload::ResourceVec;

pub struct Harness {
    pub k: u32,
    pub needs: Vec<u32>,
    /// Full demand vectors (dimension-0 projection == `needs`).
    pub demands: Vec<ResourceVec>,
    /// Full capacity vector (dimension 0 == `k`).
    pub capacity: ResourceVec,
    pub jobs: JobTable,
    fifos: ClassFifos,
    index: QueueIndex,
    pub queued: Vec<u32>,
    pub running: Vec<u32>,
    used: u32,
    used_vec: ResourceVec,
    pub now: f64,
}

impl Harness {
    /// Scalar (servers-only) harness — the original model.
    pub fn new(k: u32, needs: &[u32]) -> Harness {
        let demands: Vec<ResourceVec> = needs.iter().map(|&n| ResourceVec::scalar(n)).collect();
        Harness::with_capacity(ResourceVec::scalar(k), &demands)
    }

    /// Multiresource harness over an explicit capacity vector.
    pub fn with_capacity(capacity: ResourceVec, demands: &[ResourceVec]) -> Harness {
        let k = capacity.servers();
        let needs: Vec<u32> = demands.iter().map(|d| d.servers()).collect();
        let mut jobs = JobTable::new();
        jobs.set_prefix_threshold(k as u64);
        Harness {
            k,
            needs,
            demands: demands.to_vec(),
            capacity,
            jobs,
            fifos: ClassFifos::new(demands.len()),
            index: QueueIndex::with_demands(demands),
            queued: vec![0; demands.len()],
            running: vec![0; demands.len()],
            used: 0,
            used_vec: ResourceVec::zero(capacity.dims()),
            now: 0.0,
        }
    }

    pub fn view(&self) -> SysView<'_> {
        #[cfg(debug_assertions)]
        self.index.assert_consistent(&self.queued, &self.running);
        SysView {
            now: self.now,
            k: self.k,
            used: self.used,
            capacity: self.capacity,
            used_vec: self.used_vec,
            needs: &self.needs,
            demands: &self.demands,
            queued: &self.queued,
            running: &self.running,
            jobs: &self.jobs,
            fifos: &self.fifos,
            index: &self.index,
        }
    }

    pub fn arrive(&mut self, class: usize, t: f64) -> JobId {
        self.arrive_sized(class, t, 1.0)
    }

    pub fn arrive_sized(&mut self, class: usize, t: f64, size: f64) -> JobId {
        self.now = self.now.max(t);
        let id = self.jobs.insert(class, self.needs[class], size, t);
        self.fifos.push_back(class, JobTable::slot_of(id));
        self.index.on_enqueue(class);
        self.queued[class] += 1;
        id
    }

    /// [`arrive`](Harness::arrive) plus the engine's incremental-consult
    /// notification ([`Policy::on_arrival`]) — required when driving a
    /// policy with its consult cache enabled.
    pub fn arrive_notified(&mut self, policy: &mut dyn Policy, class: usize, t: f64) -> JobId {
        let id = self.arrive(class, t);
        policy.on_arrival(class, self.needs[class]);
        id
    }

    /// Complete a running job.
    pub fn complete(&mut self, id: JobId, t: f64) {
        self.now = self.now.max(t);
        assert_eq!(self.jobs.state(id), JobState::Running);
        let class = self.jobs.class(id);
        let need = self.jobs.need(id);
        self.used -= need;
        self.used_vec.sub_assign(&self.demands[class]);
        self.index.on_depart(class);
        self.running[class] -= 1;
        self.jobs.remove(id);
    }

    /// [`complete`](Harness::complete) plus the engine's
    /// incremental-consult notification ([`Policy::on_departure`]).
    pub fn complete_notified(&mut self, policy: &mut dyn Policy, id: JobId, t: f64) {
        let class = self.jobs.class(id);
        let need = self.jobs.need(id);
        self.complete(id, t);
        policy.on_departure(class, need);
    }

    /// Repeatedly consult the policy (as the engine does) and apply its
    /// decisions; returns all newly admitted job ids in admission order.
    pub fn consult(&mut self, policy: &mut dyn Policy) -> Vec<JobId> {
        let mut all = Vec::new();
        let mut out = Decision::default();
        loop {
            out.clear();
            policy.schedule(&self.view(), &mut out);
            if out.admit.is_empty() && out.preempt.is_empty() {
                break;
            }
            assert!(
                policy.is_preemptive() || out.preempt.is_empty(),
                "non-preemptive policy attempted preemption"
            );
            for &id in &out.preempt {
                self.apply_preempt(id);
            }
            for &id in &out.admit {
                self.apply_admit(id);
                all.push(id);
            }
            // Mirror the engine: the policy's decision was applied.
            policy.on_swap_epoch();
        }
        all
    }

    fn apply_preempt(&mut self, id: JobId) {
        self.jobs.preempt(id, self.now); // asserts Running
        let class = self.jobs.class(id);
        let need = self.jobs.need(id);
        self.used -= need;
        self.used_vec.sub_assign(&self.demands[class]);
        self.index.on_preempt(class);
        self.running[class] -= 1;
        self.queued[class] += 1;
        self.fifos.push_front(class, JobTable::slot_of(id));
    }

    fn apply_admit(&mut self, id: JobId) {
        assert!(self.jobs.is_queued(id), "admitted non-queued job");
        let class = self.jobs.class(id);
        let need = self.jobs.need(id);
        assert!(self.used + need <= self.k, "capacity violated");
        assert!(
            self.demands[class].fits_in(&self.capacity.saturating_sub(&self.used_vec)),
            "vector capacity violated"
        );
        self.fifos.remove(class, JobTable::slot_of(id));
        self.jobs.start_service(id, self.now);
        self.used += need;
        self.used_vec.add_assign(&self.demands[class]);
        self.index.on_admit(class);
        self.running[class] += 1;
        self.queued[class] -= 1;
    }

    pub fn used(&self) -> u32 {
        self.used
    }

    pub fn in_system(&self, class: usize) -> u32 {
        self.queued[class] + self.running[class]
    }
}
