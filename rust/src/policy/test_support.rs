//! A miniature system-state container: manually arrive/complete jobs and
//! collect policy decisions, without the full DES engine. Used by unit
//! tests, the property-test suite, AND the coordinator daemon (which
//! drives it from real-time events instead of simulated ones).

use crate::policy::{Decision, JobId, Policy, SysView};
use crate::sim::job::{JobState, JobTable};
use std::collections::VecDeque;

pub struct Harness {
    pub k: u32,
    pub needs: Vec<u32>,
    pub jobs: JobTable,
    pub order: VecDeque<JobId>,
    pub class_fifo: Vec<VecDeque<JobId>>,
    pub queued: Vec<u32>,
    pub running: Vec<u32>,
    used: u32,
    pub now: f64,
}

impl Harness {
    pub fn new(k: u32, needs: &[u32]) -> Harness {
        Harness {
            k,
            needs: needs.to_vec(),
            jobs: JobTable::new(),
            order: VecDeque::new(),
            class_fifo: vec![VecDeque::new(); needs.len()],
            queued: vec![0; needs.len()],
            running: vec![0; needs.len()],
            used: 0,
            now: 0.0,
        }
    }

    pub fn view(&self) -> SysView<'_> {
        SysView {
            now: self.now,
            k: self.k,
            used: self.used,
            needs: &self.needs,
            queued: &self.queued,
            running: &self.running,
            jobs: &self.jobs,
            order: &self.order,
            class_fifo: &self.class_fifo,
        }
    }

    pub fn arrive(&mut self, class: usize, t: f64) -> JobId {
        self.arrive_sized(class, t, 1.0)
    }

    pub fn arrive_sized(&mut self, class: usize, t: f64, size: f64) -> JobId {
        self.now = self.now.max(t);
        let id = self.jobs.insert(class, self.needs[class], size, t);
        self.order.push_back(id);
        self.class_fifo[class].push_back(id);
        self.queued[class] += 1;
        id
    }

    /// Complete a running job.
    pub fn complete(&mut self, id: JobId, t: f64) {
        self.now = self.now.max(t);
        let j = self.jobs.get(id);
        assert_eq!(j.state, JobState::Running);
        let (class, need) = (j.class, j.need);
        self.used -= need;
        self.running[class] -= 1;
        self.jobs.remove(id);
        while let Some(&f) = self.order.front() {
            if self.jobs.in_system(f) {
                break;
            }
            self.order.pop_front();
        }
    }

    /// Repeatedly consult the policy (as the engine does) and apply its
    /// decisions; returns all newly admitted job ids in admission order.
    pub fn consult(&mut self, policy: &mut dyn Policy) -> Vec<JobId> {
        let mut all = Vec::new();
        let mut out = Decision::default();
        loop {
            out.clear();
            policy.schedule(&self.view(), &mut out);
            if out.admit.is_empty() && out.preempt.is_empty() {
                break;
            }
            assert!(
                policy.is_preemptive() || out.preempt.is_empty(),
                "non-preemptive policy attempted preemption"
            );
            let preempt = out.preempt.clone();
            for id in preempt {
                let j = self.jobs.get_mut(id);
                assert_eq!(j.state, JobState::Running);
                j.state = JobState::Queued;
                j.epoch += 1;
                let (class, need) = (j.class, j.need);
                self.used -= need;
                self.running[class] -= 1;
                self.queued[class] += 1;
                self.class_fifo[class].push_front(id);
            }
            let admit = out.admit.clone();
            for id in admit {
                let j = self.jobs.get(id);
                assert_eq!(j.state, JobState::Queued, "admitted non-queued job");
                let (class, need) = (j.class, j.need);
                assert!(self.used + need <= self.k, "capacity violated");
                if let Some(pos) = self.class_fifo[class].iter().position(|&x| x == id) {
                    self.class_fifo[class].remove(pos);
                }
                let j = self.jobs.get_mut(id);
                j.state = JobState::Running;
                j.started = self.now;
                j.epoch += 1;
                self.used += need;
                self.running[class] += 1;
                self.queued[class] -= 1;
                all.push(id);
            }
        }
        all
    }

    pub fn used(&self) -> u32 {
        self.used
    }

    pub fn in_system(&self, class: usize) -> u32 {
        self.queued[class] + self.running[class]
    }
}
