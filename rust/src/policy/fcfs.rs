//! First-Come First-Served: admit jobs strictly in arrival order; stop at
//! the first job that does not fit (Head-of-the-Line blocking).
//!
//! Consult cache: FCFS can admit only while the head-of-line job fits,
//! so after any full scan the blocker's need is an *exact*
//! [`ConsultWatermark`] — the HoL job never changes except through our
//! own admissions (which end in a scan that refreshes the watermark) or
//! an arrival into an empty queue (handled in [`Policy::on_arrival`]).
//! Because the watermark is written by the scan itself, even the
//! fixed-point re-consult after an admission batch is skipped.

use crate::policy::{ClassId, ConsultWatermark, Decision, Policy, SysView};

#[derive(Default, Debug)]
pub struct Fcfs {
    /// Consult cache: skip while free capacity is below the watermark
    /// (= the HoL blocker's need after a full scan).
    watermark: ConsultWatermark,
}

impl Fcfs {
    pub fn new() -> Fcfs {
        Fcfs::default()
    }
}

impl Policy for Fcfs {
    fn name(&self) -> String {
        "FCFS".into()
    }

    fn schedule(&mut self, sys: &SysView<'_>, out: &mut Decision) {
        if self.watermark.blocks(sys.free()) {
            return; // HoL job still blocked: provably empty consult
        }
        // Index fit check: when even the smallest queued need exceeds the
        // free capacity (or nothing is queued at all), the scan below
        // would walk every running job only to admit nothing. The min
        // queued need is ≤ the HoL blocker's need, so it is a valid
        // conservative watermark for the skip.
        let minq = sys.min_queued_need();
        if minq > sys.free() {
            self.watermark.set(minq);
            return;
        }
        let mut free = sys.free();
        let mut blocked_need = u32::MAX;
        let admit = &mut out.admit;
        sys.for_each_in_arrival_order(&mut |id, class, running| {
            if running {
                return true; // skip jobs already in service
            }
            let need = sys.needs[class];
            if need <= free {
                admit.push(id);
                free -= need;
                true
            } else {
                blocked_need = need;
                false // head-of-line blocking: stop at first misfit
            }
        });
        // Exact watermark for the post-decision state: the scan either
        // stopped at the blocker (which stays HoL after our admissions
        // are applied, with `free` exactly as computed above) or
        // admitted the whole queue.
        self.watermark.set(blocked_need);
    }

    fn on_arrival(&mut self, _class: ClassId, need: u32) {
        // A new tail job can only become HoL if the queue was empty
        // (watermark MAX); taking the min is conservative otherwise.
        self.watermark.observe_arrival(need);
    }

    // on_swap_epoch: intentionally the default no-op — unlike the
    // min-queued-need policies, FCFS's scan computes the watermark that
    // is already exact for the post-admission state (see above), so its
    // own decisions never invalidate it.

    fn set_consult_cache(&mut self, enabled: bool) {
        self.watermark.set_enabled(enabled);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::test_support::Harness;

    #[test]
    fn head_of_line_blocks() {
        // k=4; arrivals: need-1, need-4, need-1.
        // FCFS admits the first job, then blocks on the 4-server job even
        // though the third (need-1) would fit.
        let mut h = Harness::new(4, &[1, 4]);
        h.arrive(0, 0.0); // class 0: need 1
        h.arrive(1, 0.1); // class 1: need 4
        h.arrive(0, 0.2);
        let admitted = h.consult(&mut Fcfs::new());
        assert_eq!(admitted, vec![0]); // only the first job starts
        assert_eq!(h.used(), 1);
    }

    #[test]
    fn admits_in_order_while_fitting() {
        let mut h = Harness::new(4, &[1, 4]);
        for i in 0..6 {
            h.arrive(0, i as f64 * 0.1);
        }
        let admitted = h.consult(&mut Fcfs::new());
        assert_eq!(admitted.len(), 4);
        assert_eq!(h.used(), 4);
    }

    /// Cached FCFS skips blocked consults but must admit identically to
    /// the uncached policy once the blocker fits.
    #[test]
    fn cache_skips_blocked_then_admits() {
        let mut h = Harness::new(4, &[1, 4]);
        let mut p = Fcfs::new();
        p.set_consult_cache(true);
        let a = h.arrive_notified(&mut p, 0, 0.0);
        h.arrive_notified(&mut p, 1, 0.1); // heavy blocks
        h.arrive_notified(&mut p, 0, 0.2);
        assert_eq!(h.consult(&mut p), vec![a]);
        // Blocked consults are skipped (watermark = 4 > free = 3).
        assert!(h.consult(&mut p).is_empty());
        h.complete_notified(&mut p, a, 1.0);
        // Heavy fits now; the trailing light stays HoL-blocked behind it.
        assert_eq!(h.consult(&mut p).len(), 1);
        assert_eq!(h.used(), 4);
    }
}
