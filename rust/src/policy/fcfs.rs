//! First-Come First-Served: admit jobs strictly in arrival order; stop at
//! the first job that does not fit (Head-of-the-Line blocking).
//!
//! Consult cache: FCFS admits something **iff its head-of-line job
//! fits**, and the JobTable maintains the HoL (oldest queued) job as an
//! O(1) cursor — so `hol_queued_need() > free` is the *exact*
//! empty-consult predicate, evaluated fresh on every consult with no
//! policy-side state at all (the former conservative
//! `ConsultWatermark`, which an arrival into a non-empty queue could
//! lower below the true HoL need, is gone). Like First-Fit, cached and
//! uncached consults are the same code path by construction. The
//! admission scan starts *at* the HoL cursor: every earlier job in
//! arrival order is in service by definition, so the scan is O(admitted
//! + 1) instead of O(jobs in system).

use crate::policy::{Decision, Policy, SysView};

#[derive(Default, Debug)]
pub struct Fcfs;

impl Fcfs {
    pub fn new() -> Fcfs {
        Fcfs
    }
}

impl Policy for Fcfs {
    fn name(&self) -> String {
        "FCFS".into()
    }

    fn schedule(&mut self, sys: &SysView<'_>, out: &mut Decision) {
        // Exact skip: the head of line blocks (or nothing is queued).
        // At d=1 this is exactly `hol_queued_need() > free()`.
        if !sys.hol_demand_fits() {
            return;
        }
        let admit = &mut out.admit;
        if sys.capacity.is_scalar() {
            let mut free = sys.free();
            sys.for_each_queued_in_arrival_order(&mut |id, class| {
                let need = sys.needs[class];
                if need <= free {
                    admit.push(id);
                    free -= need;
                    true
                } else {
                    false // head-of-line blocking: stop at first misfit
                }
            });
        } else {
            let mut free = sys.free_vec();
            sys.for_each_queued_in_arrival_order(&mut |id, class| {
                let demand = sys.demands[class];
                if demand.fits_in(&free) {
                    admit.push(id);
                    free.sub_assign(&demand);
                    true
                } else {
                    false // head-of-line blocking: stop at first misfit
                }
            });
        }
        debug_assert!(!admit.is_empty(), "HoL predicate admitted nothing");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::test_support::Harness;

    #[test]
    fn head_of_line_blocks() {
        // k=4; arrivals: need-1, need-4, need-1.
        // FCFS admits the first job, then blocks on the 4-server job even
        // though the third (need-1) would fit.
        let mut h = Harness::new(4, &[1, 4]);
        h.arrive(0, 0.0); // class 0: need 1
        h.arrive(1, 0.1); // class 1: need 4
        h.arrive(0, 0.2);
        let admitted = h.consult(&mut Fcfs::new());
        assert_eq!(admitted, vec![0]); // only the first job starts
        assert_eq!(h.used(), 1);
    }

    #[test]
    fn admits_in_order_while_fitting() {
        let mut h = Harness::new(4, &[1, 4]);
        for i in 0..6 {
            h.arrive(0, i as f64 * 0.1);
        }
        let admitted = h.consult(&mut Fcfs::new());
        assert_eq!(admitted.len(), 4);
        assert_eq!(h.used(), 4);
    }

    /// The exact HoL predicate: blocked consults admit nothing, and the
    /// moment the blocker fits it is admitted — with a trailing light
    /// job admissible only once it becomes HoL itself. A light arrival
    /// behind a heavy blocker must NOT unblock anything (the case the
    /// old conservative watermark had to re-consult for).
    #[test]
    fn hol_predicate_is_exact() {
        let mut h = Harness::new(4, &[1, 4]);
        let mut p = Fcfs::new();
        let a = h.arrive(0, 0.0);
        h.arrive(1, 0.1); // heavy blocks
        assert_eq!(h.consult(&mut p), vec![a]);
        assert_eq!(h.view().hol_queued_need(), 4);
        // Light arrival behind the blocker: HoL need stays 4, consult
        // stays provably empty.
        h.arrive(0, 0.2);
        assert_eq!(h.view().hol_queued_need(), 4);
        assert!(h.consult(&mut p).is_empty());
        h.complete(a, 1.0);
        // Heavy fits now; the trailing light stays HoL-blocked behind it.
        assert_eq!(h.consult(&mut p).len(), 1);
        assert_eq!(h.used(), 4);
        assert_eq!(h.view().hol_queued_need(), 1);
    }
}
