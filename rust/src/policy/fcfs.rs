//! First-Come First-Served: admit jobs strictly in arrival order; stop at
//! the first job that does not fit (Head-of-the-Line blocking).

use crate::policy::{Decision, Policy, SysView};

#[derive(Default, Debug)]
pub struct Fcfs;

impl Fcfs {
    pub fn new() -> Fcfs {
        Fcfs
    }
}

impl Policy for Fcfs {
    fn name(&self) -> String {
        "FCFS".into()
    }

    fn schedule(&mut self, sys: &SysView<'_>, out: &mut Decision) {
        let mut free = sys.free();
        sys.for_each_in_arrival_order(&mut |id, class, running| {
            if running {
                return true; // skip jobs already in service
            }
            let need = sys.needs[class];
            if need <= free {
                out.admit.push(id);
                free -= need;
                true
            } else {
                false // head-of-line blocking: stop at first misfit
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::test_support::Harness;

    #[test]
    fn head_of_line_blocks() {
        // k=4; arrivals: need-1, need-4, need-1.
        // FCFS admits the first job, then blocks on the 4-server job even
        // though the third (need-1) would fit.
        let mut h = Harness::new(4, &[1, 4]);
        h.arrive(0, 0.0); // class 0: need 1
        h.arrive(1, 0.1); // class 1: need 4
        h.arrive(0, 0.2);
        let admitted = h.consult(&mut Fcfs::new());
        assert_eq!(admitted, vec![0]); // only the first job starts
        assert_eq!(h.used(), 1);
    }

    #[test]
    fn admits_in_order_while_fitting() {
        let mut h = Harness::new(4, &[1, 4]);
        for i in 0..6 {
            h.arrive(0, i as f64 * 0.1);
        }
        let admitted = h.consult(&mut Fcfs::new());
        assert_eq!(admitted.len(), 4);
        assert_eq!(h.used(), 4);
    }
}
