//! Multiclass study (Fig 5): Static and Adaptive Quickswap vs MSF /
//! First-Fit / FCFS on the 4-class, k=15 workload of §6.3.
//!
//! Run: `cargo run --release --example multiclass`

use quickswap::experiments::{figures, Scale};
use quickswap::workload::Workload;

fn main() {
    let wl = Workload::four_class(1.0);
    println!(
        "4-class workload: k={}, needs {:?}, λ* = {:.3} (Remark 1)\n",
        wl.k,
        wl.needs(),
        wl.lambda_critical_floored()
    );
    let scale = Scale::from_env();
    let pts = figures::fig5(scale, &[2.0, 3.0, 4.0, 4.5, 4.75]);

    // Paper claim (§6.3): both Quickswap policies beat MSF and First-Fit
    // in weighted mean response time at every λ; Adaptive ≤ Static.
    let at = |policy: &str, lambda: f64| {
        pts.iter()
            .find(|p| p.policy.to_lowercase().replace('-', "").contains(policy) && p.lambda == lambda)
            .map(|p| p.result.weighted_t)
            .unwrap_or(f64::NAN)
    };
    for lambda in [4.0, 4.5, 4.75] {
        let adaptive = at("adaptiveqs", lambda);
        let msf = at("msf", lambda);
        println!(
            "λ={lambda}: AdaptiveQS E_w[T] = {adaptive:.2}, MSF = {msf:.2}  ({:.1}× better)",
            msf / adaptive
        );
    }
}
