//! End-to-end driver: every layer composing on a real workload.
//!
//! 1. Generates a one-or-all workload trace (workload substrate).
//! 2. Starts the cluster-scheduler coordinator (L3) in scaled real time
//!    with the MSF policy, serves the trace over the TCP JSONL API,
//!    and records weighted/unweighted mean response time.
//! 3. Invokes the online autotuner — which executes the AOT-compiled
//!    JAX/Pallas CTMC solver (L2+L1) through PJRT — to pick the
//!    Quickswap threshold ℓ*, hot-swaps the policy to MSFQ(ℓ*), replays
//!    the same trace, and reports the improvement.
//!
//! Run: `make artifacts && cargo run --release --example e2e_serve`
//! The headline metric (the paper's E[T]) is printed for both phases
//! and recorded in EXPERIMENTS.md.

use quickswap::coordinator::{serve_tcp, Coordinator, CoordinatorConfig};
use quickswap::workload::trace::Trace;
use quickswap::workload::Workload;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

// k=8 so the bundled msfq_solver_k8 artifact drives the autotuner.
const K: u32 = 8;
// ρ ≈ 0.956 — past the k=8 crossover where Quickswap beats MSF, so the
// autotuner must pick ℓ > 0 (it clamps its estimate at ρ = 0.95).
const LAMBDA: f64 = 4.5;
const JOBS: usize = 10_000;
const TIME_SCALE: f64 = 1e-2; // job of size 1.0 runs 10 ms: keeps OS timer slop (~0.1 ms)
// below 1% of a service time, so MSFQ's fast phase switches are faithful.

/// Serve `trace` through the coordinator's TCP API under `policy`.
/// With `tune_at_end`, ask the coordinator to autotune from its observed
/// arrival rates once the trace has been submitted (the PJRT solve runs
/// on a coordinator worker thread while the system drains).
fn serve_trace(
    policy: &str,
    wl: &Workload,
    trace: &Trace,
    tune_at_end: bool,
) -> anyhow::Result<(f64, f64, Option<u32>)> {
    let pol = quickswap::policy::build(&policy.parse()?, wl)?;
    let coord = Coordinator::spawn(
        wl,
        pol,
        CoordinatorConfig {
            time_scale: TIME_SCALE,
            autotune_every: 0,
            use_artifact: true,
            solver_iters: 20_000,
        },
    );
    let addr = serve_tcp("127.0.0.1:0", coord.handle())?;

    // Data connection: paced submissions; responses are drained by a
    // background reader so the TCP roundtrip never throttles the
    // arrival process.
    let stream = TcpStream::connect(addr)?;
    let mut writer = stream.try_clone()?;
    let reader = std::thread::spawn(move || {
        let r = BufReader::new(stream);
        let mut oks = 0usize;
        for line in r.lines() {
            match line {
                Ok(l) if l.contains("\"ok\":true") => oks += 1,
                Ok(l) => panic!("submit failed: {l}"),
                Err(_) => break,
            }
        }
        oks
    });
    // Control connection (autotune RPC). The solve runs for seconds on a
    // coordinator worker thread; the reply is awaited on its own thread
    // so trace pacing is never disturbed.
    let ctrl = TcpStream::connect(addr)?;
    let mut ctrl_w = ctrl.try_clone()?;
    let mut tune_waiter: Option<std::thread::JoinHandle<Option<u32>>> = None;

    // Absolute-deadline pacing so per-write slop does not accumulate
    // into a biased arrival-rate estimate at the coordinator.
    let t0 = Instant::now();
    for a in trace.arrivals.iter() {
        let deadline = t0 + Duration::from_secs_f64(a.t * TIME_SCALE);
        if let Some(wait) = deadline.checked_duration_since(Instant::now()) {
            std::thread::sleep(wait);
        }
        writeln!(
            writer,
            r#"{{"op":"submit","class":{},"size":{}}}"#,
            a.class, a.size
        )?;
    }
    if tune_at_end {
        writeln!(ctrl_w, r#"{{"op":"autotune"}}"#)?;
        let ctrl2 = ctrl.try_clone()?;
        tune_waiter = Some(std::thread::spawn(move || {
            let mut r = BufReader::new(ctrl2);
            let mut line = String::new();
            r.read_line(&mut line).ok()?;
            let v = quickswap::util::json::Value::parse(line.trim()).ok()?;
            let ell = v.get("ell").and_then(|e| e.as_u64()).map(|e| e as u32);
            println!("  autotuner (PJRT artifact) chose ell = {ell:?}");
            ell
        }));
    }
    writer.shutdown(std::net::Shutdown::Write)?;
    let acked = reader.join().expect("reader thread");
    anyhow::ensure!(acked == trace.arrivals.len(), "lost submissions: {acked}");
    let tuned: Option<u32> = tune_waiter.and_then(|w| w.join().ok().flatten());

    let h = coord.handle();
    anyhow::ensure!(h.drain(Duration::from_secs(180)), "coordinator did not drain");
    let stats = h.stats().expect("stats");
    println!(
        "  [{}] completed {} jobs: E[T] = {:.3}, E_w[T] = {:.3} (virtual time units)",
        stats.policy, stats.completed, stats.mean_t, stats.weighted_t
    );
    let out = (stats.mean_t, stats.weighted_t, tuned);
    coord.join();
    Ok(out)
}

fn main() -> anyhow::Result<()> {
    let wl = Workload::one_or_all(K, LAMBDA, 0.9, 1.0, 1.0);
    println!(
        "end-to-end: k={K}, λ={LAMBDA}, ρ={:.3}, {JOBS} jobs over TCP, time scale {TIME_SCALE}",
        wl.load()
    );
    let trace = Trace::generate(&wl, JOBS, 2025);

    println!("\nphase 1: observe under MSF (coordinator + TCP API), then tune");
    println!("         from the observed rates via the PJRT solver artifact");
    let (msf_t, msf_tw, ell) = serve_trace("msf", &wl, &trace, true)?;
    let ell_star = ell.ok_or_else(|| anyhow::anyhow!("autotune produced no threshold"))?;
    anyhow::ensure!(ell_star > 0, "expected ell > 0 at rho≈0.95, got {ell_star}");

    println!("\nphase 2: redeploy as MSFQ(ℓ*={ell_star}) and replay the same trace");
    let (tuned_t, tuned_tw, _) = serve_trace(&format!("msfq:{ell_star}"), &wl, &trace, false)?;

    println!("\n==== end-to-end summary ====");
    println!("MSF            E[T] = {msf_t:.3}   E_w[T] = {msf_tw:.3}");
    println!("MSFQ(ℓ={ell_star})      E[T] = {tuned_t:.3}   E_w[T] = {tuned_tw:.3}");
    println!(
        "improvement: {:.2}× unweighted, {:.2}× weighted",
        msf_t / tuned_t,
        msf_tw / tuned_tw
    );
    Ok(())
}
