//! Quickstart: simulate MSF vs MSFQ(k−1) on the paper's one-or-all
//! workload and print the headline comparison (this is Fig 3's λ = 7.5
//! point at reduced scale).
//!
//! Run: `cargo run --release --example quickstart`

use quickswap::analysis::{analyze, MsfqParams};
use quickswap::policy::PolicyId;
use quickswap::sim::{run_policy, SimConfig};
use quickswap::workload::Workload;

fn main() -> anyhow::Result<()> {
    // k = 32 servers, 90% of arrivals need 1 server, 10% need all 32;
    // both classes have mean size 1. λ = 7.5 ⇒ load ρ ≈ 0.96.
    let wl = Workload::one_or_all(32, 7.5, 0.9, 1.0, 1.0);
    println!(
        "one-or-all workload: k={}, λ={}, load ρ={:.3}\n",
        wl.k,
        wl.total_rate(),
        wl.load()
    );

    let cfg = SimConfig::default().with_completions(400_000);
    for policy in [
        PolicyId::Fcfs,
        PolicyId::FirstFit,
        PolicyId::Msf,
        PolicyId::Msfq(Some(31)),
    ] {
        let r = run_policy(&wl, &policy, &cfg, 42)?;
        println!("{}", r.summary());
    }

    // The Theorem-2 calculator agrees with the MSFQ simulation:
    let a = analyze(&MsfqParams::standard(32, 31, 7.5, 0.9)).expect("stable");
    println!("\nTheorem-2 analysis of MSFQ(31): E[T] = {:.3}", a.et);
    println!("MSFQ beats MSF by switching phases faster (Quickswap).");
    Ok(())
}
