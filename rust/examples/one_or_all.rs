//! Full one-or-all study: reproduces Figs 1–4 (time series, threshold
//! sweep, λ sweep with analysis overlay, phase durations).
//!
//! Run: `QS_SCALE=full cargo run --release --example one_or_all`
//! (QS_SCALE=bench for a faster pass; outputs land in results/).

use quickswap::experiments::{figures, Scale};

fn main() {
    let scale = Scale::from_env();
    println!("scale: {scale:?}\n");

    println!("--- Fig 1: #jobs in system over time (MSF vs MSFQ) ---");
    let f1 = figures::fig1(scale);
    let (msf, msfq) = (&f1[0], &f1[1]);
    println!(
        "MSF holds {:.1}× more jobs on average than MSFQ\n",
        msf.mean_n / msfq.mean_n
    );

    println!("--- Fig 2: E[T] vs quickswap threshold ℓ ---");
    figures::fig2(scale, 7.5, &[0, 1, 2, 4, 8, 16, 24, 28, 31]);

    println!("\n--- Fig 3: E[T] and E[T^w] vs λ, all policies ---");
    figures::fig3(scale, &[4.0, 5.0, 6.0, 6.75, 7.25, 7.5]);

    println!("\n--- Fig 4: phase durations vs λ ---");
    figures::fig4(scale, &[6.0, 6.75, 7.25, 7.5]);

    println!("\nCSV series written under results/ (fig1..fig4).");
}
