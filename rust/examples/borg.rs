//! Borg-trace study (Fig 6, C.7, D.8): the 26-class, k=2048 workload
//! derived from the Google Borg 2019 traces (synthesized per DESIGN.md
//! §4 — calibrated to the paper's reported statistics).
//!
//! Run: `cargo run --release --example borg` (QS_SCALE=full for paper
//! scale). Writes results/fig6_borg.csv, fig7_fairness.csv,
//! fig8_preemptive.csv.

use quickswap::experiments::{figures, Scale};
use quickswap::workload::borg::borg_workload;

fn main() {
    let wl = borg_workload(1.0);
    println!(
        "Borg-derived workload: {} classes, k={}, λ* = {:.3}",
        wl.num_classes(),
        wl.k,
        wl.lambda_critical_floored()
    );
    let heavy_rate: f64 = wl.classes.iter().filter(|c| c.need() >= 512).map(|c| c.rate).sum();
    println!(
        "heavy group: {:.3}% of jobs, {:.1}% of load\n",
        100.0 * heavy_rate / wl.total_rate(),
        100.0 * (0..26)
            .filter(|&c| wl.classes[c].need() >= 512)
            .map(|c| wl.rho_class(c))
            .sum::<f64>()
            / (0..26).map(|c| wl.rho_class(c)).sum::<f64>()
    );

    let scale = Scale::from_env();
    let lambdas = [2.0, 3.0, 4.0, 4.5];

    println!("--- Fig 6: weighted E[T] (nonpreemptive policies) ---");
    let pts = figures::fig6(scale, &lambdas, false);

    println!("\n--- Fig C.7: fairness ---");
    figures::fig7(&pts);

    println!("\n--- Fig D.8: including preemptive ServerFilling ---");
    figures::fig6(scale, &lambdas, true);
}
