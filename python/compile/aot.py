"""AOT export: lower the L2 solver/sweep to HLO *text* artifacts.

HLO text (not `.serialize()`): the image's xla_extension 0.5.1 rejects
jax>=0.5 protos with 64-bit instruction ids; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (consumed by rust/src/runtime):
  artifacts/msfq_solver_k8.hlo.txt    small solver (tests, fast)
  artifacts/msfq_solver_k32.hlo.txt   paper-scale solver (k = 32)
  artifacts/msfq_sweep_k8.hlo.txt     full threshold sweep, k = 8
  artifacts/meta.json                 shapes + input/output layouts

Inputs of every solver artifact: params f32[8] (see kernels.ref), iters
i32 scalar. Output: f32[16] metric vector (model.METRICS order). The
sweep artifact returns (f32[k,16], i32, i32).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.ref import NPARAMS
from .model import NMETRICS, default_shape, solve, sweep


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_solver(k: int, shape):
    params = jax.ShapeDtypeStruct((NPARAMS,), jnp.float32)
    iters = jax.ShapeDtypeStruct((), jnp.int32)
    fn = lambda p, i: solve(p, i, shape=shape)  # noqa: E731
    return jax.jit(fn).lower(params, iters)


def lower_sweep(k: int, shape):
    params = jax.ShapeDtypeStruct((NPARAMS,), jnp.float32)
    iters = jax.ShapeDtypeStruct((), jnp.int32)
    fn = lambda p, i: sweep(p, i, shape=shape, k=k)  # noqa: E731
    return jax.jit(fn).lower(params, iters)


# (name, k, shape, lower): shapes are the truncation used at export time.
def artifact_specs():
    # k=8 uses a deeper light-queue truncation (A=128) than
    # default_shape so solves stay trustworthy (boundary mass ≪ 5%) up
    # to ρ ≈ 0.95 — the autotuner's clamped operating point.
    return [
        ("msfq_solver_k8", 8, (128, 32, 9), lower_solver),
        ("msfq_solver_k32", 32, (256, 64, 33), lower_solver),
        ("msfq_sweep_k8", 8, (128, 32, 9), lower_sweep),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--only", default=None, help="emit a single artifact")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    meta = {
        "params_layout": ["lam1", "lamk", "mu1", "muk", "ell", "k", "_", "_"],
        "metrics_layout": [
            "en1", "enk", "et1", "etk", "et", "etw", "m1", "m23", "m4",
            "idle", "blocked1", "blockedk", "residual", "mass", "_", "_",
        ],
        "nmetrics": NMETRICS,
        "artifacts": {},
    }
    for name, k, shape, lower in artifact_specs():
        if args.only and args.only != name:
            continue
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = to_hlo_text(lower(k, shape))
        with open(path, "w") as f:
            f.write(text)
        meta["artifacts"][name] = {
            "k": k,
            "shape": list(shape),
            "kind": "sweep" if "sweep" in name else "solver",
            "file": f"{name}.hlo.txt",
        }
        print(f"wrote {path} ({len(text) / 1e6:.2f} MB, shape {shape})")

    meta_path = os.path.join(args.out_dir, "meta.json")
    # Merge with an existing meta.json when --only is used.
    if args.only and os.path.exists(meta_path):
        with open(meta_path) as f:
            old = json.load(f)
        old["artifacts"].update(meta["artifacts"])
        meta = old
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()
