"""Layer-2 JAX model: the MSFQ CTMC solver built on the L1 Pallas kernel.

`solve` power-iterates the uniformized chain from the empty state and
reduces the stationary distribution to the response-time metrics the
Rust coordinator consumes (autotuning and analysis cross-checks).
`sweep` evaluates every Quickswap threshold 0..k-1 and returns the metric
matrix plus the E[T]-optimal threshold — the autotuner artifact.

Everything here is build-time Python: `aot.py` lowers these functions to
HLO text once, and the Rust runtime executes the artifacts via PJRT.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.ref import (
    NPARAMS,
    P_ELL,
    P_K,
    P_LAM1,
    P_LAMK,
    P_MU1,
    P_MUK,
    make_params,
    uniform_step_ref,
)
from .kernels.uniform_step import uniform_step

# Output-vector layout (documented in artifacts/meta.json for Rust).
METRICS = [
    "en1",        # 0  E[N1]
    "enk",        # 1  E[Nk]
    "et1",        # 2  E[T] light (Little)
    "etk",        # 3  E[T] heavy
    "et",         # 4  overall E[T]
    "etw",        # 5  load-weighted E[T^w]
    "m1",         # 6  fraction of time serving heavies (phase 1)
    "m23",        # 7  light-serving fraction (phases 2+3)
    "m4",         # 8  drain fraction (phase 4)
    "idle",       # 9  idle fraction
    "blocked1",   # 10 truncation-boundary mass (lights)
    "blockedk",   # 11 truncation-boundary mass (heavies)
    "residual",   # 12 L1 delta of the final step
    "mass",       # 13 total probability (conservation check, ~1)
]
NMETRICS = 16


def initial_state(shape):
    """Point mass on the empty system (0, 0, z=0)."""
    p0 = jnp.zeros(shape, jnp.float32)
    return p0.at[0, 0, 0].set(1.0)


def metrics_from_p(p, params, residual):
    A, B, _Z = p.shape
    f = jnp.float32
    a = jax.lax.broadcasted_iota(f, p.shape, 0)
    b = jax.lax.broadcasted_iota(f, p.shape, 1)
    lam1, lamk = params[P_LAM1], params[P_LAMK]
    mu1, muk, k = params[P_MU1], params[P_MUK], params[P_K]

    en1 = jnp.sum(a * p)
    enk = jnp.sum(b * p)
    m1 = jnp.sum(p[:, 1:, 0])
    idle = jnp.sum(p[:, 0, 0])
    m23 = jnp.sum(p[:, :, 1])
    m4 = jnp.sum(p[:, :, 2:])
    blocked1 = jnp.sum(p[A - 1, :, :])
    blockedk = jnp.sum(p[:, B - 1, :])
    l1e = lam1 * (1.0 - blocked1)
    lke = lamk * (1.0 - blockedk)
    et1 = en1 / l1e
    etk = enk / lke
    et = (en1 + enk) / (l1e + lke)
    rho1 = lam1 / mu1
    rhok = k * lamk / muk
    etw = (rho1 * et1 + rhok * etk) / (rho1 + rhok)
    out = jnp.stack(
        [
            en1, enk, et1, etk, et, etw,
            m1, m23, m4, idle,
            blocked1, blockedk, residual, jnp.sum(p),
        ]
    )
    return jnp.concatenate([out, jnp.zeros(NMETRICS - out.shape[0], f)])


def _solve_impl(params, iters, shape, step_fn):
    p0 = initial_state(shape)

    def body(_, p):
        return step_fn(p, params)

    p = jax.lax.fori_loop(0, iters, body, p0)
    p_next = step_fn(p, params)
    residual = jnp.sum(jnp.abs(p_next - p))
    return metrics_from_p(p_next, params, residual)


@functools.partial(jax.jit, static_argnames=("shape",))
def solve(params, iters, *, shape):
    """Stationary metrics of the MSFQ chain after `iters` power steps.

    params: f32[NPARAMS] (see kernels.ref.make_params); iters: i32 scalar.
    Returns f32[NMETRICS].
    """
    return _solve_impl(params, iters, shape, uniform_step)


@functools.partial(jax.jit, static_argnames=("shape",))
def solve_ref(params, iters, *, shape):
    """Same solver on the pure-jnp reference step (oracle path)."""
    return _solve_impl(params, iters, shape, uniform_step_ref)


@functools.partial(jax.jit, static_argnames=("shape", "k"))
def sweep(base_params, iters, *, shape, k):
    """Evaluate all thresholds ell = 0..k-1: returns (metrics[k, NMETRICS],
    best_ell_by_et, best_ell_by_etw). The autotuner artifact."""

    def one(ell):
        p = base_params.at[P_ELL].set(ell.astype(jnp.float32))
        return _solve_impl(p, iters, shape, uniform_step)

    ells = jnp.arange(k, dtype=jnp.int32)
    metrics = jax.lax.map(one, ells)
    et = metrics[:, 4]
    etw = metrics[:, 5]
    best_et = jnp.argmin(jnp.where(jnp.isfinite(et), et, jnp.inf))
    best_etw = jnp.argmin(jnp.where(jnp.isfinite(etw), etw, jnp.inf))
    return metrics, best_et.astype(jnp.int32), best_etw.astype(jnp.int32)


def default_shape(k, n1_mult=8, nk_mult=2):
    """Truncation heuristic: A = n1_mult·k, B = max(32, nk_mult·k), Z = k+1."""
    return (int(n1_mult * k), max(32, int(nk_mult * k)), int(k) + 1)


def solve_py(k, ell, lam1, lamk, mu1=1.0, muk=1.0, iters=20000, shape=None,
             use_ref=False):
    """Convenience wrapper for tests/scripts."""
    shape = shape or default_shape(k)
    params = jnp.asarray(make_params(lam1, lamk, mu1, muk, ell, k))
    fn = solve_ref if use_ref else solve
    out = fn(params, jnp.int32(iters), shape=shape)
    return {name: float(out[i]) for i, name in enumerate(METRICS)}
