"""Pure-jnp reference ("oracle") for the MSFQ CTMC uniformization step.

The one-or-all MSFQ system is a CTMC over states (n1, nk, z):

  z = 0      serving a heavy job (or idle when n1 = nk = 0),
  z = 1      light-serving phase (paper phases 2 and 3: M/M/k on lights),
  z = 1+u    drain phase (paper phase 4) with u lights still in service,
             u in 1..k-1 (only u <= ell is reachable).

`uniform_step_ref` applies one uniformized power step
    p <- p + (inflow(p) - outrate .* p) / Lambda
to a dense probability tensor p[A, B, Z] (A = n1 truncation + 1, etc.).
Arrivals at the truncation boundary are deferred (no out-rate), so
probability mass is conserved exactly.

This file is the correctness oracle for the Pallas kernel
(`uniform_step.py`) and mirrors the sparse Rust solver
(rust/src/analysis/ctmc.rs) transition for transition.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Parameter-vector layout shared by ref, kernel, model and the Rust
# runtime (artifacts/meta.json documents it for consumers).
P_LAM1, P_LAMK, P_MU1, P_MUK, P_ELL, P_K = 0, 1, 2, 3, 4, 5
NPARAMS = 8


def make_params(lam1, lamk, mu1, muk, ell, k):
    """Pack system parameters into the f32 vector the kernels consume."""
    v = np.zeros(NPARAMS, dtype=np.float32)
    v[P_LAM1], v[P_LAMK], v[P_MU1], v[P_MUK] = lam1, lamk, mu1, muk
    v[P_ELL], v[P_K] = float(ell), float(k)
    return v


def uniformization_rate(params):
    lam1, lamk, mu1, muk = (
        params[P_LAM1],
        params[P_LAMK],
        params[P_MU1],
        params[P_MUK],
    )
    k = params[P_K]
    return lam1 + lamk + jnp.maximum(k * mu1, muk)


def _shift(p, axis, by):
    """Shift `p` so out[i] = p[i - by] along `axis`, zero-filled."""
    if by == 0:
        return p
    pad = [(0, 0)] * p.ndim
    if by > 0:
        pad[axis] = (by, 0)
        sl = [slice(None)] * p.ndim
        sl[axis] = slice(0, p.shape[axis])
        return jnp.pad(p, pad)[tuple(sl)]
    pad[axis] = (0, -by)
    sl = [slice(None)] * p.ndim
    sl[axis] = slice(-by, p.shape[axis] - by)
    return jnp.pad(p, pad)[tuple(sl)]


def uniform_step_ref(p, params):
    """One uniformized step of the MSFQ CTMC. p: f32[A, B, Z]."""
    A, B, Z = p.shape
    lam1, lamk, mu1, muk = (
        params[P_LAM1],
        params[P_LAMK],
        params[P_MU1],
        params[P_MUK],
    )
    ell, k = params[P_ELL], params[P_K]
    lam = uniformization_rate(params)

    f = jnp.float32
    a = jnp.arange(A, dtype=f)[:, None, None]  # n1 index
    b = jnp.arange(B, dtype=f)[None, :, None]  # nk index
    z = jnp.arange(Z, dtype=f)[None, None, :]  # phase index

    is_z0 = (z == 0).astype(f)
    is_z1 = (z == 1).astype(f)
    is_drain = (z >= 2).astype(f)
    u = jnp.maximum(z - 1.0, 0.0)  # lights in service in drain states

    # ---- out-rates ------------------------------------------------------
    q = jnp.zeros_like(p)
    q += lam1 * (a < A - 1).astype(f)
    q += lamk * (b < B - 1).astype(f)
    q += is_z0 * muk * (b >= 1).astype(f)
    q += is_z1 * jnp.minimum(a, k) * mu1 * (a >= 1).astype(f)
    q += is_drain * u * mu1 * (a >= 1).astype(f)

    inflow = jnp.zeros_like(p)

    # ---- light arrivals (rate lam1), source (a-1, b, z) -----------------
    p_a = _shift(p, 0, 1)  # p[a-1, b, z]
    # Normal: phase unchanged. In z=0 this requires b >= 1 (otherwise the
    # arrival triggers a dispatch, handled below).
    keep = is_z1 + is_drain + is_z0 * (b >= 1).astype(f)
    inflow += lam1 * p_a * keep
    # Dispatch from (a-1, 0, 0): new light count m = a lands in z=1 if
    # m > ell else in drain z = 1+m.
    src_l = _shift(p[:, :, 0] * (jnp.arange(B, dtype=f)[None, :] == 0), 0, 1)  # (A,B)
    m_gt = (a > ell).astype(f) * (a >= 1).astype(f)
    m_le = (a <= ell).astype(f) * (a >= 1).astype(f)
    diag = (z == a + 1.0).astype(f)  # dest z = 1 + n1
    inflow += lam1 * src_l[:, :, None] * (m_gt * is_z1 + m_le * diag)

    # ---- heavy arrivals (rate lamk), source (a, b-1, z) ------------------
    inflow += lamk * _shift(p, 1, 1)

    # ---- heavy completions (z=0, rate muk) -------------------------------
    p_b = _shift(p[:, :, 0], 1, -1)  # p[a, b+1, 0]
    # Still heavies left: stay z=0 with b >= 1.
    inflow += muk * (p_b * (b[:, :, 0] >= 1).astype(f))[:, :, None] * is_z0
    # Last heavy done: source (a, 1, 0) -> dispatch(a, 0).
    src_h = p[:, 1, 0] if B > 1 else jnp.zeros((A,), f)  # (A,)
    av = jnp.arange(A, dtype=f)
    at_b0 = (b == 0).astype(f)
    gt = (av > ell).astype(f) * (av >= 1).astype(f)
    le = (av <= ell).astype(f) * (av >= 1).astype(f)
    idle = (av == 0).astype(f)
    term = (
        gt[:, None] * (z[0] == 1).astype(f)
        + le[:, None] * (z[0] == av[:, None] + 1.0).astype(f)
        + idle[:, None] * (z[0] == 0).astype(f)
    )  # (A, Z)
    inflow += muk * src_h[:, None, None] * at_b0 * term[:, None, :]

    # ---- light completions in z=1 (rate min(a+1,k)*mu1) ------------------
    p1_a = _shift(p[:, :, 1], 0, -1)  # p[a+1, b, 1]
    rate1 = jnp.minimum(a[:, :, 0] + 1.0, k) * mu1
    # a > ell: stay in z=1.
    stay = (a[:, :, 0] > ell).astype(f)
    inflow += (rate1 * stay * p1_a)[:, :, None] * is_z1
    # a <= ell, ell >= 1: trigger -> drain with u = ell (z = 1 + ell).
    # (Reachable only with a == ell, but we mirror the sparse solver's
    # branch exactly so the oracle comparison holds on any input.)
    trig = ((a[:, :, 0] <= ell) & (ell >= 1))
    inflow += (rate1 * trig.astype(f) * p1_a)[:, :, None] * (z == ell + 1.0).astype(f)
    # ell == 0 and a == 0: lights exhausted -> z=0 (serve heavy or idle).
    exh = ((a[:, :, 0] == 0) & (ell == 0))
    inflow += (rate1 * exh.astype(f) * p1_a)[:, :, None] * is_z0

    # ---- light completions in drain z' = z+1 -> z (z >= 2) ---------------
    p_d = _shift(_shift(p, 0, -1), 2, -1)  # p[a+1, b, z+1]
    rate_d = u + 1.0  # source had u+1 in service
    inflow += (z >= 2).astype(f) * rate_d * mu1 * p_d
    # D_1 exit: source (a+1, b, 2), rate mu1 -> dispatch(a, b).
    src_d = _shift(p[:, :, 2], 0, -1) if Z > 2 else jnp.zeros((A, B), f)  # (A,B)
    b2 = b[:, :, 0]
    a2 = a[:, :, 0]
    disp_z0 = (b2 >= 1) | (a2 == 0)  # serve heavy, or idle
    disp_z1 = (b2 == 0) & (a2 > ell)
    disp_dg = (b2 == 0) & (a2 >= 1) & (a2 <= ell)
    inflow += mu1 * (src_d * disp_z0.astype(f))[:, :, None] * is_z0
    inflow += mu1 * (src_d * disp_z1.astype(f))[:, :, None] * is_z1
    inflow += mu1 * (src_d * disp_dg.astype(f))[:, :, None] * diag

    return p + (inflow - q * p) / lam


def build_generator_dense(A, B, Z, params):
    """Dense uniformized transition matrix P (numpy, python loops): the
    slow-but-obviously-correct oracle used by the test-suite to verify
    `uniform_step_ref` (and transitively the Pallas kernel)."""
    lam1, lamk, mu1, muk = (float(params[i]) for i in range(4))
    ell, k = int(params[P_ELL]), int(params[P_K])
    lam = lam1 + lamk + max(k * mu1, muk)
    n = A * B * Z

    def idx(a, b, z):
        return (a * B + b) * Z + z

    def dispatch(a, b):
        if b >= 1:
            return (a, b, 0)
        if a > ell:
            return (a, 0, 1)
        if a >= 1:
            return (a, 0, 1 + a)
        return (0, 0, 0)

    P = np.zeros((n, n), dtype=np.float64)
    for a in range(A):
        for b in range(B):
            for z in range(Z):
                s = idx(a, b, z)
                q = 0.0
                if a < A - 1:
                    if z == 0 and b == 0:
                        d = dispatch(a + 1, 0)
                    else:
                        d = (a + 1, b, z)
                    P[s, idx(*d)] += lam1 / lam
                    q += lam1
                if b < B - 1:
                    P[s, idx(a, b + 1, z)] += lamk / lam
                    q += lamk
                if z == 0 and b >= 1:
                    d = (a, b - 1, 0) if b - 1 >= 1 else dispatch(a, 0)
                    P[s, idx(*d)] += muk / lam
                    q += muk
                elif z == 1 and a >= 1:
                    rate = min(a, k) * mu1
                    if a - 1 > ell:
                        d = (a - 1, b, 1)
                    elif ell >= 1:
                        d = (a - 1, b, 1 + ell)
                    else:
                        d = dispatch(0, b)
                    P[s, idx(*d)] += rate / lam
                    q += rate
                elif z >= 2 and a >= 1:
                    u = z - 1
                    rate = u * mu1
                    d = (a - 1, b, z - 1) if u - 1 >= 1 else dispatch(a - 1, b)
                    P[s, idx(*d)] += rate / lam
                    q += rate
                P[s, s] += 1.0 - q / lam
    return P
