"""Layer-1 Pallas kernel: the MSFQ CTMC uniformization step.

The whole state tensor p[A, B, Z] lives in one VMEM-resident block —
for the paper-scale artifact (A, B, Z) = (256, 64, 33) that is ~2.2 MB of
f32, comfortably inside a TPU core's ~16 MB VMEM, so the power iteration
streams zero bytes to/from HBM between steps. The step itself is a
shift-and-mask stencil (~14 shifted multiply-adds), i.e. a VPU-bound
elementwise kernel; there is no MXU work in this paper's hot loop.
DESIGN.md §Hardware-Adaptation records the footprint/roofline analysis.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO (numerically
identical; verified against `ref.py` and a dense-matrix oracle by
python/tests).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NPARAMS, P_ELL, P_K, P_LAM1, P_LAMK, P_MU1, P_MUK


def _shift(x, axis, by):
    """out[i] = x[i - by] along `axis`, zero-filled (in-kernel version)."""
    if by == 0:
        return x
    pad = [(0, 0)] * x.ndim
    sl = [slice(None)] * x.ndim
    if by > 0:
        pad[axis] = (by, 0)
        sl[axis] = slice(0, x.shape[axis])
    else:
        pad[axis] = (0, -by)
        sl[axis] = slice(-by, x.shape[axis] - by)
    return jnp.pad(x, pad)[tuple(sl)]


def _uniform_step_kernel(p_ref, params_ref, out_ref):
    """Pallas kernel body: one uniformized power step (see ref.py for the
    transition-by-transition derivation; this is the same stencil)."""
    p = p_ref[...]
    params = params_ref[...]
    A, B, Z = p.shape
    lam1 = params[P_LAM1]
    lamk = params[P_LAMK]
    mu1 = params[P_MU1]
    muk = params[P_MUK]
    ell = params[P_ELL]
    k = params[P_K]
    lam = lam1 + lamk + jnp.maximum(k * mu1, muk)

    f = jnp.float32
    a = jax.lax.broadcasted_iota(f, (A, B, Z), 0)
    b = jax.lax.broadcasted_iota(f, (A, B, Z), 1)
    z = jax.lax.broadcasted_iota(f, (A, B, Z), 2)

    is_z0 = (z == 0).astype(f)
    is_z1 = (z == 1).astype(f)
    is_drain = (z >= 2).astype(f)
    u = jnp.maximum(z - 1.0, 0.0)

    # Out-rates.
    q = lam1 * (a < A - 1).astype(f)
    q += lamk * (b < B - 1).astype(f)
    q += is_z0 * muk * (b >= 1).astype(f)
    q += is_z1 * jnp.minimum(a, k) * mu1 * (a >= 1).astype(f)
    q += is_drain * u * mu1 * (a >= 1).astype(f)

    diag = (z == a + 1.0).astype(f)  # dest z = 1 + n1
    at_b0 = (b == 0).astype(f)

    # Light arrivals.
    p_a = _shift(p, 0, 1)
    keep = is_z1 + is_drain + is_z0 * (b >= 1).astype(f)
    inflow = lam1 * p_a * keep
    src_l = _shift(p[:, :, 0:1] * (b[:, :, 0:1] == 0).astype(f), 0, 1)  # (A,B,1)
    m_gt = ((a > ell) & (a >= 1)).astype(f)
    m_le = ((a <= ell) & (a >= 1)).astype(f)
    inflow += lam1 * src_l * (m_gt * is_z1 + m_le * diag)

    # Heavy arrivals.
    inflow += lamk * _shift(p, 1, 1)

    # Heavy completions.
    p_b = _shift(p[:, :, 0:1], 1, -1)  # p[a, b+1, 0]
    inflow += muk * p_b * (b >= 1).astype(f) * is_z0
    src_h = p[:, 1:2, 0:1] if B > 1 else jnp.zeros((A, 1, 1), f)  # p[a,1,0]
    gt = ((a > ell) & (a >= 1)).astype(f)
    le = ((a <= ell) & (a >= 1)).astype(f)
    idle = (a == 0).astype(f)
    inflow += muk * src_h * at_b0 * (gt * is_z1 + le * diag + idle * is_z0)

    # Light completions in z=1.
    p1_a = _shift(p[:, :, 1:2], 0, -1)  # p[a+1, b, 1]
    rate1 = jnp.minimum(a + 1.0, k) * mu1
    stay = (a > ell).astype(f)
    inflow += rate1 * stay * p1_a * is_z1
    trig = ((a <= ell) & (ell >= 1)).astype(f)
    inflow += rate1 * trig * p1_a * (z == ell + 1.0).astype(f)
    exh = ((a == 0) & (ell == 0)).astype(f)
    inflow += rate1 * exh * p1_a * is_z0

    # Drain-phase completions (z >= 2), and the D_1 exit dispatch.
    p_d = _shift(_shift(p, 0, -1), 2, -1)  # p[a+1, b, z+1]
    inflow += is_drain * (u + 1.0) * mu1 * p_d
    if Z > 2:
        src_d = _shift(p[:, :, 2:3], 0, -1)  # p[a+1, b, 2]
    else:
        src_d = jnp.zeros((A, B, 1), f)
    disp_z0 = ((b >= 1) | (a == 0)).astype(f)
    disp_z1 = ((b == 0) & (a > ell)).astype(f)
    disp_dg = ((b == 0) & (a >= 1) & (a <= ell)).astype(f)
    inflow += mu1 * src_d * (disp_z0 * is_z0 + disp_z1 * is_z1 + disp_dg * diag)

    out_ref[...] = p + (inflow - q * p) / lam


@functools.partial(jax.jit, static_argnames=())
def uniform_step(p, params):
    """One uniformized MSFQ power step as a Pallas call (interpret mode).

    p: f32[A, B, Z] probability tensor; params: f32[NPARAMS].
    """
    assert params.shape == (NPARAMS,)
    return pl.pallas_call(
        _uniform_step_kernel,
        out_shape=jax.ShapeDtypeStruct(p.shape, jnp.float32),
        interpret=True,
    )(p.astype(jnp.float32), params.astype(jnp.float32))


def vmem_footprint_bytes(shape):
    """Estimated VMEM working set of the kernel: in + out + ~3 shifted
    temporaries of the full block (the XLA fusion reuses buffers; this is
    the conservative upper bound quoted in DESIGN.md)."""
    import math

    elems = math.prod(shape)
    return elems * 4 * 5
