"""Build-time compile path: L2 JAX model + L1 Pallas kernels + AOT export.

Never imported at runtime — `make artifacts` lowers everything to HLO
text under artifacts/, which the Rust runtime executes via PJRT.
"""
