"""L1 correctness: the Pallas uniformization kernel vs the pure-jnp
reference vs a dense-matrix oracle built with plain python loops.

This is the CORE correctness signal for the accelerated layers: if these
pass, the HLO artifacts compute exactly the chain the Rust sparse solver
(rust/src/analysis/ctmc.rs) and the paper's §4.2 definition describe.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (
    build_generator_dense,
    make_params,
    uniform_step_ref,
)
from compile.kernels.uniform_step import uniform_step, vmem_footprint_bytes

jax.config.update("jax_enable_x64", False)


def random_p(shape, seed):
    rng = np.random.default_rng(seed)
    p = rng.random(shape).astype(np.float32)
    return p / p.sum()


def dist_shapes():
    return [(8, 4, 5), (12, 6, 9), (6, 3, 3)]


@pytest.mark.parametrize("shape", dist_shapes())
@pytest.mark.parametrize("ell", [0, 1, 3])
def test_ref_matches_dense_oracle(shape, ell):
    A, B, Z = shape
    k = Z - 1
    if ell >= k:
        pytest.skip("ell < k required")
    params = make_params(1.5, 0.3, 1.0, 0.8, ell, k)
    P = build_generator_dense(A, B, Z, params)
    # Rows are stochastic.
    np.testing.assert_allclose(P.sum(axis=1), 1.0, atol=1e-12)
    p = random_p(shape, seed=hash((shape, ell)) % 2**31)
    want = (p.reshape(-1) @ P).reshape(shape)
    got = np.asarray(uniform_step_ref(jnp.asarray(p), jnp.asarray(params)))
    np.testing.assert_allclose(got, want, atol=1e-6)


@pytest.mark.parametrize("shape", dist_shapes())
@pytest.mark.parametrize("ell", [0, 2])
def test_kernel_matches_ref(shape, ell):
    k = shape[2] - 1
    params = jnp.asarray(make_params(2.0, 0.4, 1.0, 1.0, ell, k))
    p = jnp.asarray(random_p(shape, seed=3))
    ref = uniform_step_ref(p, params)
    ker = uniform_step(p, params)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref), atol=1e-6)


def test_mass_conserved_many_steps():
    shape = (16, 8, 5)
    params = jnp.asarray(make_params(1.0, 0.2, 1.0, 1.0, 3, 4))
    p = jnp.zeros(shape, jnp.float32).at[0, 0, 0].set(1.0)
    for _ in range(200):
        p = uniform_step(p, params)
    assert abs(float(p.sum()) - 1.0) < 1e-4
    assert float(p.min()) > -1e-6


@settings(max_examples=30, deadline=None)
@given(
    A=st.integers(4, 14),
    B=st.integers(2, 8),
    k=st.integers(2, 8),
    ell_frac=st.floats(0.0, 1.0),
    lam1=st.floats(0.1, 4.0),
    lamk=st.floats(0.05, 1.0),
    mu1=st.floats(0.3, 2.0),
    muk=st.floats(0.3, 2.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_vs_oracle_hypothesis(A, B, k, ell_frac, lam1, lamk, mu1, muk, seed):
    """Property sweep: arbitrary shapes/rates/thresholds — kernel ==
    dense oracle (through ref equality + ref-vs-oracle equality)."""
    Z = k + 1
    ell = min(int(ell_frac * k), k - 1)
    params = make_params(lam1, lamk, mu1, muk, ell, k)
    p = random_p((A, B, Z), seed)
    P = build_generator_dense(A, B, Z, params)
    want = (p.reshape(-1) @ P).reshape(p.shape)
    got = np.asarray(uniform_step(jnp.asarray(p), jnp.asarray(params)))
    np.testing.assert_allclose(got, want, atol=2e-6)


def test_vmem_footprint_paper_scale():
    # Paper-scale block (k=32): must fit comfortably in 16 MB VMEM.
    assert vmem_footprint_bytes((256, 64, 33)) < 16 * 2**20 * 0.8
