"""L2 correctness: solver convergence, metric sanity, sweep behaviour.

The k=4 numbers here are cross-checked against the Rust sparse CTMC
solver (rust/src/analysis/ctmc.rs tests) and the DES simulator; the
values asserted below were independently produced by that solver.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import make_params
from compile.model import METRICS, default_shape, solve_py, sweep


def test_metrics_layout_stable():
    # The Rust runtime indexes this layout; do not reorder silently.
    assert METRICS[:6] == ["en1", "enk", "et1", "etk", "et", "etw"]
    assert METRICS[12] == "residual"


def test_low_load_sanity():
    # k=4, lambda=1.0, p1=0.9. Note MSFQ(ell=3) makes later lights queue
    # behind a solo drain even at low load, so E[T1] sits well above the
    # bare service time 1/mu1 = 1 (cross-checked with the Rust solver).
    m = solve_py(4, 3, 0.9, 0.1, iters=4000, shape=(48, 16, 5))
    assert abs(m["mass"] - 1.0) < 1e-3
    assert m["blocked1"] < 1e-6 and m["blockedk"] < 1e-6
    assert 1.0 < m["et1"] < 3.0, m
    assert m["residual"] < 1e-5


def test_matches_rust_ctmc_value():
    # Rust solver: k=4, ell=3, lambda=2.9, p1=0.9 → E[T] ≈ 11.70;
    # and ell=0 (MSF) → E[T] ≈ 13.35 (same truncation family).
    msfq = solve_py(4, 3, 2.9 * 0.9, 2.9 * 0.1, iters=60000, shape=(384, 96, 5))
    assert abs(msfq["et"] - 11.70) / 11.70 < 0.02, msfq["et"]
    msf = solve_py(4, 0, 2.9 * 0.9, 2.9 * 0.1, iters=60000, shape=(384, 96, 5))
    assert abs(msf["et"] - 13.35) / 13.35 < 0.02, msf["et"]
    assert msfq["et"] < msf["et"]


def test_ref_and_kernel_paths_agree_end_to_end():
    a = solve_py(4, 2, 1.5, 0.2, iters=3000, shape=(48, 16, 5), use_ref=False)
    b = solve_py(4, 2, 1.5, 0.2, iters=3000, shape=(48, 16, 5), use_ref=True)
    for key in ("en1", "enk", "et", "m1", "m23", "m4"):
        np.testing.assert_allclose(a[key], b[key], rtol=1e-4), key


def test_phase_fractions_sum_to_one():
    m = solve_py(4, 3, 2.0, 0.25, iters=20000, shape=(96, 32, 5))
    total = m["m1"] + m["m23"] + m["m4"] + m["idle"]
    assert abs(total - 1.0) < 1e-3, total


@pytest.mark.slow
def test_sweep_prefers_nonzero_threshold():
    k = 4
    shape = (192, 64, 5)
    params = jnp.asarray(make_params(2.9 * 0.9, 2.9 * 0.1, 1.0, 1.0, 0, k))
    metrics, best_et, best_etw = sweep(params, jnp.int32(40000), shape=shape, k=k)
    metrics = np.asarray(metrics)
    assert metrics.shape == (k, 16)
    # E[T] at the chosen threshold beats MSF (ell = 0).
    assert metrics[int(best_et), 4] <= metrics[0, 4]
    assert int(best_et) > 0


def test_default_shape_reasonable():
    A, B, Z = default_shape(32)
    assert Z == 33 and A >= 4 * 32 and B >= 32
